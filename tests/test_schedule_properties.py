"""Property tests for the mask-aware renormalization (DESIGN.md §Sim).

Runs under real hypothesis when installed, else under the deterministic
fallback registered by ``tests/conftest.py`` (seeded random sampling,
same ``given``/``settings`` surface).

Invariants, over random masks / topologies:

* the masked, renormalized phase-1 rows re-sum to the unmasked total
  (1.0 in convex-combination mode) over the surviving clients only;
* receivers are forced present under EVERY mask: CWFL cluster-heads
  (`cwfl.participation_weights`) and the COTAF server
  (`baselines.cotaf_participation`);
* an all-masked round is a no-op at the engine level: no client
  transmits, the consensus (and the reported accuracy) stays frozen.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import TopologyConfig, baselines, cwfl, make_topology

K = 10


@pytest.fixture(scope="module")
def topo():
    return make_topology(jax.random.PRNGKey(3),
                         TopologyConfig(num_clients=K, num_hotspots=3))


@pytest.fixture(scope="module")
def state(topo):
    return cwfl.setup(topo, cwfl.CWFLConfig(num_clusters=3, snr_db=40.0),
                      jax.random.PRNGKey(5))


def _mask_from_bits(bits):
    m = np.zeros((K,), np.float32)
    m[: len(bits)] = np.asarray(bits[:K], np.float32)
    return jnp.asarray(m)


@settings(max_examples=12, deadline=None)
@given(bits=st.lists(st.booleans(), min_size=K, max_size=K))
def test_masked_rows_resum_to_unmasked_total(state, bits):
    """Ã's convex renormalization must hold for the *surviving* clients:
    every masked row re-sums to exactly the unmasked total (1.0), and
    absent non-head columns are exactly zero (they transmit no power)."""
    mask = _mask_from_bits(bits)
    params = {"w": jax.random.normal(jax.random.PRNGKey(11), (K, 24))}
    A, std1, *_ = cwfl.round_coefficients(state, params, mask=mask)
    A = np.asarray(A)
    A_full, std1_full, *_ = cwfl.round_coefficients(state, params, mask=None)
    np.testing.assert_allclose(A.sum(axis=1),
                               np.asarray(A_full).sum(axis=1), atol=1e-5)
    head = np.asarray(state.plan.head_mask) > 0
    absent = (np.asarray(mask) == 0) & ~head
    assert np.all(A[:, absent] == 0.0)
    # losing row mass can only RAISE the renormalized receiver noise
    assert np.all(np.asarray(std1) >= np.asarray(std1_full) - 1e-9)


@settings(max_examples=12, deadline=None)
@given(bits=st.lists(st.booleans(), min_size=K, max_size=K))
def test_receivers_forced_present_under_every_mask(state, topo, bits):
    mask = _mask_from_bits(bits)
    part = cwfl.participation_weights(state, mask)
    head = np.asarray(state.plan.head_mask) > 0
    assert np.all(np.asarray(part)[head] == 1.0)
    # members keep exactly their mask bit
    np.testing.assert_array_equal(np.asarray(part)[~head],
                                  np.asarray(mask)[~head])

    cstate = baselines.cotaf_setup(topo, jax.random.PRNGKey(6), snr_db=40.0)
    cpart = baselines.cotaf_participation(cstate, mask)
    assert float(np.asarray(cpart)[int(cstate.server)]) == 1.0


@settings(max_examples=4, deadline=None)
@given(k=st.integers(min_value=4, max_value=9),
       seed=st.integers(min_value=0, max_value=2 ** 16))
def test_all_masked_round_is_noop(k, seed):
    """Every client straggling ⇒ the sync is skipped: consensus (and the
    accuracy computed from it) is frozen at init while local training
    still moves the per-client losses.  Randomized over K and data
    seeds; tiny workload so the property stays tier-1-fast."""
    from repro.data import (SyntheticImageConfig, make_synthetic_images,
                            partition_iid)
    from repro.models import make_mnist_mlp, nll_loss
    from repro.sim import Scenario, ScheduleConfig, run_rounds
    from repro.training import FLConfig

    key = jax.random.PRNGKey(seed)
    dcfg = SyntheticImageConfig.mnist_like(num_train=32 * k, num_test=64)
    (xtr, ytr), (xte, yte) = make_synthetic_images(key, dcfg)
    topo = make_topology(jax.random.fold_in(key, 1),
                         TopologyConfig(num_clients=k, num_hotspots=2))
    xs, ys = partition_iid(jax.random.fold_in(key, 2), xtr, ytr, k)
    init, apply = make_mnist_mlp(hidden=(8,))
    loss = lambda p, x, y: nll_loss(apply(p, x), y)
    cfg = FLConfig(strategy="cwfl", rounds=2, snr_db=40.0, batch_size=16,
                   num_clusters=2, eval_samples=64, seed=seed % 97)
    sc = Scenario(name="blackout",
                  schedule=ScheduleConfig(num_stragglers=k,
                                          straggler_period=1))
    h = run_rounds(init, apply, loss, topo, xs, ys, xte, yte, cfg,
                   scenario=sc)
    acc = np.asarray(h["test_acc"])
    assert np.isfinite(np.asarray(h["train_loss"])).all()
    assert (acc == acc[0]).all()                  # consensus never updated
    loss_arr = np.asarray(h["train_loss"])
    assert not (loss_arr == loss_arr[0]).all()    # local training progressed
