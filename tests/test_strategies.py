"""Strategy-API conformance (repro.strategies, DESIGN.md §Strategy-API).

Parametrized over EVERY registered strategy — a new `register_strategy`
entry is automatically held to the same contract:

* the init state is a registered pytree (jit/scan-carry legal);
* an all-ones participation mask is bit-identical to no mask at all
  (state rebuild AND aggregation);
* the masked receive rule keeps forced-present nodes (CWFL heads, the
  COTAF server) and never drops a participant;
* ``state_from_view`` + ``aggregate`` are jit/vmap-legal inside a
  2-round ``lax.scan`` (the engine's execution shape);
* the observability hooks conform (repro.obs): ``telemetry`` returns the
  required keys with finite, fixed-shape leaves and traces under
  jit ∘ vmap ∘ scan; ``channel_uses`` matches the paper's §IV arithmetic.

CI runs this module with ``-W error::DeprecationWarning`` scoped to
``repro.*`` — the library itself must not lean on its own deprecated
aliases (`repro.training.STRATEGIES`).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import TopologyConfig, channel as ch, make_topology
from repro.sim.processes import ChannelView
from repro.strategies import (COTAFStrategy, CWFLStrategy,
                              DecentralizedStrategy, FedAvgStrategy,
                              PAPER_MU_PROX, available_strategies,
                              get_strategy, register_strategy)
from repro.strategies.base import _REGISTRY
from repro.training import FLConfig

K = 8
ALL = available_strategies()
SNR_DB = 40.0


@pytest.fixture(scope="module")
def topo():
    return make_topology(jax.random.PRNGKey(7),
                         TopologyConfig(num_clients=K, num_hotspots=3))


def _view(topo):
    return ChannelView(link_gain=topo.link_gain, link_snr=topo.link_snr,
                       adjacency=topo.adjacency)


def _stacked(key):
    kw, kb = jax.random.split(key)
    return {"w": jax.random.normal(kw, (K, 5, 3), jnp.float32),
            "b": jax.random.normal(kb, (K, 3), jnp.float32)}


def _cfg(name):
    return FLConfig(strategy=name, num_clusters=3)


def _noise_var(topo):
    return ch.snr_db_to_noise_var(topo.total_power, SNR_DB)


def _trees_bitwise_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return (len(la) == len(lb)
            and all(bool(jnp.array_equal(x, y)) for x, y in zip(la, lb)))


# ---------------------------------------------------------------------------
# Conformance: every registered strategy.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ALL)
def test_state_is_registered_pytree(topo, name):
    """States ride scan carries and jit arguments — flatten/unflatten must
    round-trip the exact type, and identity-jit must accept them."""
    s = get_strategy(name)
    state = s.init(topo, jax.random.PRNGKey(0), _cfg(name), snr_db=SNR_DB)
    leaves, treedef = jax.tree.flatten(state)
    rebuilt = jax.tree.unflatten(treedef, leaves)
    assert type(rebuilt) is type(state)
    jitted = jax.jit(lambda st: st)(state)
    assert _trees_bitwise_equal(jitted, state)


@pytest.mark.parametrize("name", ALL)
def test_aggregate_output_structure(topo, name):
    """aggregate keeps the K-stacked structure and returns a consensus
    shaped like ONE client's tree."""
    s = get_strategy(name)
    state = s.init(topo, jax.random.PRNGKey(0), _cfg(name), snr_db=SNR_DB)
    stacked = _stacked(jax.random.PRNGKey(1))
    new, consensus = s.aggregate(stacked, state, jax.random.PRNGKey(2))
    assert (jax.tree.structure(new) == jax.tree.structure(stacked)
            == jax.tree.structure(consensus))
    for n, x, c in zip(jax.tree.leaves(new), jax.tree.leaves(stacked),
                       jax.tree.leaves(consensus)):
        assert n.shape == x.shape and c.shape == x.shape[1:]
        assert bool(jnp.isfinite(n).all())


@pytest.mark.parametrize("name", ALL)
def test_all_ones_mask_bit_identical_to_unmasked(topo, name):
    """A full-participation round must be indistinguishable — bitwise —
    from an unmasked one, in both the state rebuild and the aggregation
    (the engine's all-ones-mask == static-path contract)."""
    s = get_strategy(name)
    view = _view(topo)
    nv = _noise_var(topo)
    state0 = s.init(topo, jax.random.PRNGKey(0), _cfg(name), snr_db=SNR_DB)
    ones = jnp.ones((K,), jnp.float32)

    st_masked = s.state_from_view(state0, view, nv, mask=ones)
    st_plain = s.state_from_view(state0, view, nv, mask=None)
    assert _trees_bitwise_equal(st_masked, st_plain)

    stacked = _stacked(jax.random.PRNGKey(1))
    key = jax.random.PRNGKey(2)
    out_masked = s.aggregate(stacked, st_masked, key, mask=ones)
    out_plain = s.aggregate(stacked, st_plain, key, mask=None)
    assert _trees_bitwise_equal(out_masked, out_plain)


@pytest.mark.parametrize("name", ALL)
def test_receive_mask_keeps_forced_present(topo, name):
    """Receive-side rule: nobody who participated is dropped, and the
    nodes the aggregation forces present (CWFL heads, the COTAF server —
    they HOLD the aggregate) stay present under any mask, including
    all-zeros.  ``None`` is only legal when the aggregate itself encodes
    absences (decentralized's pruned Metropolis graph)."""
    s = get_strategy(name)
    state = s.init(topo, jax.random.PRNGKey(0), _cfg(name), snr_db=SNR_DB)
    rng = np.random.default_rng(3)
    for mask_np in (np.zeros(K), np.ones(K),
                    (rng.random(K) < 0.5).astype(np.float32)):
        mask = jnp.asarray(mask_np, jnp.float32)
        recv = s.receive_mask(state, mask)
        if recv is None:
            assert isinstance(s, DecentralizedStrategy)
            continue
        recv = np.asarray(recv)
        assert recv.shape == (K,)
        # never drop a participant
        assert (recv >= mask_np - 1e-7).all()
        if isinstance(s, CWFLStrategy):
            heads = np.asarray(state.plan.head_mask) > 0
            assert (recv[heads] == 1.0).all()
            np.testing.assert_array_equal(recv[~heads], mask_np[~heads])
        elif isinstance(s, COTAFStrategy):
            server = int(np.asarray(state.server))
            assert recv[server] == 1.0
            others = np.arange(K) != server
            np.testing.assert_array_equal(recv[others], mask_np[others])
        elif isinstance(s, FedAvgStrategy):
            np.testing.assert_array_equal(recv, mask_np)


@pytest.mark.parametrize("name", ALL)
def test_state_from_view_scan_vmap_legal(topo, name):
    """The per-round rebuild must trace inside jit ∘ vmap ∘ scan — the
    exact shape `repro.sim.engine` runs it in (2 rounds, 2 seeds)."""
    s = get_strategy(name)
    cfg = _cfg(name)
    view = _view(topo)
    nv = _noise_var(topo)

    def traj(seed):
        key = jax.random.PRNGKey(seed)
        state0 = s.init(topo, key, cfg, snr_db=SNR_DB)
        stacked = _stacked(jax.random.fold_in(key, 1))

        def body(carry, k):
            state = s.state_from_view(state0, view, nv)
            new, cons = s.aggregate(carry, state, k)
            return new, sum(jnp.sum(c) for c in jax.tree.leaves(cons))

        keys = jax.random.split(jax.random.fold_in(key, 2), 2)
        _, sums = jax.lax.scan(body, stacked, keys)
        return sums

    sums = jax.jit(jax.vmap(traj))(jnp.arange(2))
    assert sums.shape == (2, 2)
    assert bool(jnp.isfinite(sums).all())


@pytest.mark.parametrize("name", ALL)
def test_telemetry_hook_conformance(topo, name):
    """Observability contract (repro.obs): every strategy's telemetry
    pytree has the required keys, fixed shapes, finite float leaves — and
    stays legal under jit ∘ vmap ∘ 2-round lax.scan, the exact shape the
    engine records it in."""
    s = get_strategy(name)
    state = s.init(topo, jax.random.PRNGKey(0), _cfg(name), snr_db=SNR_DB)
    losses = jnp.abs(jax.random.normal(jax.random.PRNGKey(3), (K,)))
    stacked = _stacked(jax.random.PRNGKey(1))
    new, consensus = s.aggregate(stacked, state, jax.random.PRNGKey(2))

    for mask in (None, jnp.ones((K,), jnp.float32)):
        t = s.telemetry(state, losses=losses, stacked=stacked,
                        new_stacked=new, consensus=consensus, mask=mask)
        assert set(t) == {"cluster_loss", "participants",
                          "consensus_drift", "extras"}
        assert t["cluster_loss"].ndim == 1
        assert t["consensus_drift"].shape == t["cluster_loss"].shape
        assert t["participants"].shape == ()
        assert float(t["participants"]) == K       # full participation
        assert isinstance(t["extras"], dict)
        for leaf in jax.tree.leaves(t):
            assert bool(jnp.isfinite(leaf).all())

    def traj(seed):
        key = jax.random.PRNGKey(seed)
        st0 = _stacked(jax.random.fold_in(key, 1))

        def body(carry, k):
            new_c, cons = s.aggregate(carry, state, k)
            t = s.telemetry(state, losses=losses, stacked=carry,
                            new_stacked=new_c, consensus=cons)
            return new_c, t
        keys = jax.random.split(jax.random.fold_in(key, 2), 2)
        _, tele = jax.lax.scan(body, st0, keys)
        return tele

    tele = jax.jit(jax.vmap(traj))(jnp.arange(2))
    for leaf in jax.tree.leaves(tele):
        assert leaf.shape[:2] == (2, 2)            # (seeds, rounds) stacked
        assert bool(jnp.isfinite(leaf).all())


def test_channel_uses_per_strategy():
    """The paper's §IV per-round cost arithmetic, strategy by strategy
    (the quantity the in-scan `repro.obs.ledger` accumulates)."""
    C = 3
    assert get_strategy("cwfl").channel_uses(K, num_clusters=C) \
        == C * (C - 1) + C
    assert get_strategy("decentralized").channel_uses(K) == K * (K - 1)
    # masked round: the effective participant count drives P(P−1)
    assert get_strategy("decentralized").channel_uses(
        K, participants=3.0) == 6.0
    assert get_strategy("cotaf").channel_uses(K) == 1
    assert get_strategy("fedavg").channel_uses(K) == 0
    # prox variants share their base strategy's channel accounting
    assert get_strategy("cwfl_prox").channel_uses(K, num_clusters=C) \
        == get_strategy("cwfl").channel_uses(K, num_clusters=C)


# ---------------------------------------------------------------------------
# Capability flags + prox variants.
# ---------------------------------------------------------------------------

def test_capability_flags():
    cwfl, cotaf = get_strategy("cwfl"), get_strategy("cotaf")
    fedavg, dec = get_strategy("fedavg"), get_strategy("decentralized")
    assert cwfl.supports_client_sharding and cwfl.water_fills \
        and cwfl.reclusters and not cwfl.needs_graph
    assert cotaf.water_fills and not cotaf.supports_client_sharding
    assert dec.needs_graph and not dec.water_fills
    assert not (fedavg.supports_client_sharding or fedavg.needs_graph
                or fedavg.water_fills or fedavg.reclusters)


def test_prox_variants_are_first_class():
    """cwfl_prox/cotaf_prox: same class (same channel math, same flags),
    paper µ_p baked in, overridable per run via FLConfig.mu_prox."""
    for base_name, prox_name in (("cwfl", "cwfl_prox"),
                                 ("cotaf", "cotaf_prox")):
        base, prox = get_strategy(base_name), get_strategy(prox_name)
        assert type(prox) is type(base)
        assert prox.mu_prox == PAPER_MU_PROX and base.mu_prox == 0.0
        assert prox.effective_mu_prox(0.0) == PAPER_MU_PROX
        assert prox.effective_mu_prox(0.3) == 0.3     # explicit cfg wins
        assert base.effective_mu_prox(0.0) == 0.0


# ---------------------------------------------------------------------------
# Registry semantics.
# ---------------------------------------------------------------------------

def test_unknown_strategy_error_lists_registered_names():
    with pytest.raises(KeyError) as ei:
        get_strategy("nope")
    msg = str(ei.value)
    assert "unknown strategy" in msg
    for name in available_strategies():
        assert name in msg


def test_error_message_includes_newly_registered_names():
    name = "_test_registered_strategy"
    register_strategy(name, CWFLStrategy(name=name))
    try:
        with pytest.raises(KeyError, match=name):
            get_strategy("nope")
        assert name in available_strategies()
    finally:
        _REGISTRY.pop(name)


def test_register_rejects_duplicates_and_non_strategies():
    with pytest.raises(ValueError, match="already registered"):
        register_strategy("cwfl", CWFLStrategy(name="cwfl"))
    with pytest.raises(TypeError, match="Strategy"):
        register_strategy("_bogus", object())
    # replace=True is the sanctioned overwrite path
    register_strategy("_tmp", FedAvgStrategy(name="_tmp"))
    try:
        register_strategy("_tmp", FedAvgStrategy(name="_tmp"),
                          replace=True)
    finally:
        _REGISTRY.pop("_tmp")


def test_register_strategy_decorator_form():
    @register_strategy("_decorated")
    @dataclasses.dataclass(frozen=True)
    class _DecoratedStrategy(FedAvgStrategy):
        pass

    try:
        s = get_strategy("_decorated")
        assert isinstance(s, _DecoratedStrategy)
        assert s.name == "_decorated"
    finally:
        _REGISTRY.pop("_decorated")


def test_get_strategy_passes_instances_through():
    s = CWFLStrategy(name="adhoc")
    assert get_strategy(s) is s


# ---------------------------------------------------------------------------
# Deprecated compatibility surface.
# ---------------------------------------------------------------------------

def test_deprecated_strategies_mapping_warns_and_works(topo):
    from repro.training import STRATEGIES

    with pytest.warns(DeprecationWarning, match="repro.strategies"):
        setup_fn, aggregate_fn = STRATEGIES["cwfl"]
    state = setup_fn(topo, jax.random.PRNGKey(0), num_clusters=3,
                     snr_db=SNR_DB)
    stacked = _stacked(jax.random.PRNGKey(1))
    old = aggregate_fn(stacked, state, jax.random.PRNGKey(2))
    new = get_strategy("cwfl").aggregate(stacked, state,
                                         jax.random.PRNGKey(2))
    assert _trees_bitwise_equal(old, new)
    with pytest.warns(DeprecationWarning):
        assert sorted(STRATEGIES) == available_strategies()


def test_scenario_default_strategy_resolves_through_registry():
    from repro.sim import Scenario, get_scenario
    assert get_scenario("straggler-prox").default_strategy().name == "cwfl_prox"
    assert Scenario().default_strategy().name == "cwfl"        # fallback
    with pytest.raises(KeyError, match="unknown strategy"):
        Scenario(name="bad", strategy="nope").default_strategy()


def test_scenario_pin_override_warns():
    """A scenario's pinned strategy can't silently lose to the config:
    the engine warns when cfg.strategy overrides the pin."""
    from goldens.generate import workload
    from repro.sim import Scenario, run_rounds

    init, apply, loss, topo, xs, ys, xte, yte = workload()
    sc = Scenario(name="pinned", strategy="cwfl_prox")
    cfg = FLConfig(strategy="cwfl", rounds=1, snr_db=40.0, eval_samples=64)
    with pytest.warns(UserWarning, match="pins strategy"):
        run_rounds(init, apply, loss, topo, xs, ys, xte, yte, cfg,
                   scenario=sc)


# ---------------------------------------------------------------------------
# End-to-end: prox strategies through the engine registry path.
# ---------------------------------------------------------------------------

def test_cwfl_prox_end_to_end_differs_from_cwfl():
    """`cwfl_prox` runs through run_rounds by NAME (registry path) and the
    proximal local objective actually bites — the trajectory departs from
    plain cwfl on the identical seed/key schedule."""
    from goldens.generate import workload
    from repro.sim import run_rounds

    init, apply, loss, topo, xs, ys, xte, yte = workload()
    # batch 16 ⇒ several local SGD steps per round — FedProx is exactly
    # inert at the very first local step (θ = θ_g), so a 1-step round
    # could not distinguish the variants
    kw = dict(rounds=2, snr_db=40.0, eval_samples=256, seed=0,
              batch_size=16)
    h_prox = run_rounds(init, apply, loss, topo, xs, ys, xte, yte,
                        FLConfig(strategy="cwfl_prox", **kw))
    h_base = run_rounds(init, apply, loss, topo, xs, ys, xte, yte,
                        FLConfig(strategy="cwfl", **kw))
    prox_loss = np.asarray(h_prox["train_loss"])
    assert np.isfinite(prox_loss).all()
    assert not np.array_equal(prox_loss, np.asarray(h_base["train_loss"]))
