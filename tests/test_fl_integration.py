"""CWFL-in-training integration: the linearity equivalence (weighted loss
⇔ explicit consensus of per-client grads) and the FL plan invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist.fl_integration import FLPlan, make_fl_plan


@pytest.fixture(scope="module")
def plan():
    return make_fl_plan(num_clients=16, num_clusters=4,
                        key=jax.random.PRNGKey(0), snr_db=40.0)


def test_beta_is_distribution(plan):
    beta = plan.beta
    assert beta.shape == (16,)
    assert np.all(beta >= 0)
    np.testing.assert_allclose(beta.sum(), 1.0, rtol=1e-5)


def test_example_weights_mean_one(plan):
    w = plan.example_weights(256)
    assert w.shape == (256,)
    np.testing.assert_allclose(w.mean(), 1.0, rtol=1e-5)
    # examples of the same client share a weight
    c = plan.client_of_example(256)
    for k in range(16):
        vals = w[c == k]
        assert np.allclose(vals, vals[0])


def test_weighted_loss_equals_explicit_consensus(plan):
    """KEY equivalence (DESIGN.md §3 shard mode): grad of the β-weighted
    mean loss == Σ_k β_k grad_k of per-client mean losses."""
    d, K, n = 5, 16, 4          # n examples per client
    key = jax.random.PRNGKey(1)
    X = jax.random.normal(key, (K * n, d))
    y = jax.random.normal(jax.random.fold_in(key, 1), (K * n,))
    theta = jax.random.normal(jax.random.fold_in(key, 2), (d,))
    w_ex = jnp.asarray(plan.example_weights(K * n))

    def weighted_loss(theta):
        pred = X @ theta
        per_ex = (pred - y) ** 2
        return jnp.mean(per_ex * w_ex)

    g_weighted = jax.grad(weighted_loss)(theta)

    # explicit per-client grads + β-weighted consensus
    beta = jnp.asarray(plan.beta)

    def client_loss(theta, k):
        pred = X[k * n:(k + 1) * n] @ theta
        return jnp.mean((pred - y[k * n:(k + 1) * n]) ** 2)

    g_explicit = sum(beta[k] * jax.grad(client_loss)(theta, k)
                     for k in range(K))
    np.testing.assert_allclose(np.asarray(g_weighted),
                               np.asarray(g_explicit), rtol=1e-4, atol=1e-5)


def test_noise_std_positive_and_snr_monotone():
    stds = []
    for snr in (10.0, 30.0, 50.0):
        p = make_fl_plan(16, 4, jax.random.PRNGKey(0), snr_db=snr)
        stds.append(p.noise_std)
    assert stds[0] > stds[1] > stds[2] > 0.0


def test_cluster_weights_rows_normalized(plan):
    B = plan.cluster_weights
    np.testing.assert_allclose(B.sum(axis=1), 1.0, rtol=1e-5)
    assert np.all(B >= 0)
