"""repro.checkpoint: pytree save/load, bf16 bit-exact wire format, errors.

The resume-determinism contract of ``run_rounds`` (DESIGN.md §Faults)
reduces to this layer restoring every carry leaf bit-exactly — including
bfloat16, which ``np.savez`` cannot serialize natively and which a
float32 detour would silently round-trip through a value conversion.
"""
import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np
import pytest

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint


def _tree(dtype=jnp.float32):
    k = jax.random.PRNGKey(0)
    return {
        "w": jax.random.normal(k, (4, 3), jnp.float32).astype(dtype),
        "b": jnp.arange(3, dtype=jnp.float32).astype(dtype),
        "step": jnp.asarray(7, jnp.int32),
    }


def test_roundtrip_f32_bitwise(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, 3, t)
    r = load_checkpoint(tmp_path, jax.tree.map(jnp.zeros_like, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_roundtrip_bf16_bitwise(tmp_path):
    """bf16 rides the wire as raw uint16 bit patterns (tree.json records
    the true dtype) — the restore must be a view, not a value cast."""
    t = _tree(jnp.bfloat16)
    t["w"] = t["w"].at[0, 0].set(jnp.asarray(3.0e38, jnp.bfloat16))
    save_checkpoint(tmp_path, 0, t)
    r = load_checkpoint(tmp_path, jax.tree.map(jnp.zeros_like, t))
    assert r["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(t["w"]).view(np.uint16),
        np.asarray(r["w"]).view(np.uint16))
    # and the npz itself holds uint16, so numpy alone can read it back
    import numpy.lib.npyio  # noqa: F401  (documents the plain-npz claim)
    raw = np.load(tmp_path / "step_00000000" / "arrays.npz")
    assert raw["w"].dtype == np.uint16
    assert np.asarray(r["w"]).view(np.uint16).tolist() == raw["w"].tolist()
    assert raw["w"].view(ml_dtypes.bfloat16).dtype == ml_dtypes.bfloat16


def test_latest_step_and_explicit_step(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, 1, t)
    save_checkpoint(tmp_path, 4, jax.tree.map(lambda x: x + 1, t))
    assert latest_step(tmp_path) == 4
    r1 = load_checkpoint(tmp_path, t, step=1)
    r4 = load_checkpoint(tmp_path, t)
    np.testing.assert_array_equal(np.asarray(r1["b"]), np.asarray(t["b"]))
    np.testing.assert_array_equal(np.asarray(r4["b"]),
                                  np.asarray(t["b"]) + 1)


def test_missing_checkpoint_errors_name_the_location(tmp_path):
    with pytest.raises(FileNotFoundError, match=str(tmp_path)):
        load_checkpoint(tmp_path, _tree())
    # a step dir that exists but was never completed (no arrays.npz)
    (tmp_path / "step_00000002").mkdir()
    with pytest.raises(FileNotFoundError, match="step_00000002"):
        load_checkpoint(tmp_path, _tree(), step=2)


def test_template_mismatch_errors_name_leaf_and_dir(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, 5, t)
    bad_shape = dict(t, w=jnp.zeros((2, 2), jnp.float32))
    with pytest.raises(ValueError, match=r"w.*step_00000005"):
        load_checkpoint(tmp_path, bad_shape, step=5)
    bad_tree = dict(t, extra=jnp.zeros(()))
    with pytest.raises(KeyError, match="extra"):
        load_checkpoint(tmp_path, bad_tree, step=5)
