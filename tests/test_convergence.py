"""Theorem 1: O(1/T) convergence of CWFL on a strongly-convex quadratic.

Clients hold f_k(θ) = ½‖θ − a_k‖² (L = µ = 1). NOTE the paper's objective
(eq. 1) is the p_k-WEIGHTED sum F(θ) = Σ p_k f_k(θ) with the same p_k that
appear in the OTA aggregation — so CWFL's optimum θ* is the SNR/power-
weighted combination of the a_k, NOT their uniform mean. We therefore
measure the error against the empirical fixed point of the noiseless
dynamics, and check (a) O(1/T)-like decay toward it and (b) the noisy floor
(Q₂) decreases with SNR."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cwfl
from repro.core.topology import TopologyConfig, make_topology
from repro.optim import inverse_time_schedule


def _setup(key, K=12, d=16, snr_db=60.0):
    k_topo, k_state, k_data = jax.random.split(key, 3)
    topo = make_topology(k_topo, TopologyConfig(num_clients=K,
                                                num_hotspots=3))
    state = cwfl.setup(topo, cwfl.CWFLConfig(num_clusters=3, snr_db=snr_db),
                       k_state)
    a = jax.random.normal(k_data, (K, d))
    return topo, state, a


def _noiseless(state):
    return cwfl.CWFLState(
        plan=state.plan, client_power=state.client_power,
        total_power=state.total_power,
        head_noise_std=state.head_noise_std * 0.0,
        consensus_noise_std=state.consensus_noise_std * 0.0,
        mix=state.mix)


def run_cwfl_quadratic(T, snr_db, key=jax.random.PRNGKey(0), K=12, d=16,
                       E=1, theta_star=None, noiseless=False):
    """Returns per-round squared error of the consensus to ``theta_star``
    (default: empirical fixed point from a long noiseless run)."""
    k_run = jax.random.fold_in(key, 123)
    topo, state, a = _setup(key, K=K, d=d, snr_db=snr_db)
    if noiseless:
        state = _noiseless(state)
    if theta_star is None:
        theta_star = fixed_point(key, K=K, d=d)
    sched = inverse_time_schedule(mu=1.0, gamma=12.0)

    theta = {"x": jnp.zeros((K, d))}
    errs = []
    for t in range(T):
        eta = sched(jnp.asarray(t, jnp.float32))
        for _ in range(E):
            theta = {"x": theta["x"] - eta * (theta["x"] - a)}
        theta, cons = cwfl.aggregate(theta, state,
                                     jax.random.fold_in(k_run, t))
        errs.append(float(jnp.sum((cons["x"] - theta_star) ** 2)))
    return np.asarray(errs)


_FP_CACHE = {}


def fixed_point(key, K=12, d=16, T=400):
    """Empirical optimum: consensus of the noiseless dynamics run long."""
    k = (tuple(np.asarray(key).tolist()), K, d)
    if k in _FP_CACHE:
        return _FP_CACHE[k]
    topo, state, a = _setup(key, K=K, d=d)
    state = _noiseless(state)
    sched = inverse_time_schedule(mu=1.0, gamma=12.0)
    theta = {"x": jnp.zeros((K, d))}
    for t in range(T):
        eta = sched(jnp.asarray(t, jnp.float32))
        theta = {"x": theta["x"] - eta * (theta["x"] - a)}
        theta, cons = cwfl.aggregate(theta, state, jax.random.PRNGKey(0))
    _FP_CACHE[k] = cons["x"]
    return cons["x"]


@pytest.mark.slow
def test_noiseless_error_decays_like_one_over_t():
    errs = run_cwfl_quadratic(T=120, snr_db=60.0, noiseless=True)
    assert errs[-1] < errs[30] / 2.0
    sm = np.convolve(errs, np.ones(10) / 10, mode="valid")
    assert sm[-1] < sm[0] / 5.0


@pytest.mark.slow
def test_noise_floor_matches_snr_ordering():
    """Final error floor decreases with SNR (Q₂ shrinks; Theorem 1)."""
    floors = []
    for snr in (10.0, 30.0, 60.0):
        errs = run_cwfl_quadratic(T=80, snr_db=snr,
                                  key=jax.random.PRNGKey(1))
        floors.append(errs[-10:].mean())
    assert floors[0] > floors[2]


def test_converges_to_neighborhood_of_fixed_point():
    errs = run_cwfl_quadratic(T=60, snr_db=60.0, key=jax.random.PRNGKey(2))
    # high SNR: error near the fixed point shrinks well below the initial one
    assert errs[-1] < 0.1 * errs[0]


def test_weighted_not_uniform_optimum():
    """CWFL's fixed point is the SNR-weighted combination, distinct from the
    uniform mean whenever powers are heterogeneous (paper eq. 1 weights)."""
    key = jax.random.PRNGKey(3)
    topo, state, a = _setup(key)
    fp = fixed_point(key)
    uniform = a.mean(0)
    assert float(jnp.sum((fp - uniform) ** 2)) > 1e-4
