"""Regenerate the committed golden-trajectory fixtures.

    PYTHONPATH=src python tests/goldens/generate.py

Runs the scenario engine's ``paper-static`` protocol (tiny T=4, K=8
synthetic-MNIST workload — the exact setup of ``tests/test_goldens.py``)
for all four strategies and stores the per-round train-loss/test-accuracy
histories as raw float32 BIT PATTERNS (uint32 hex), so the regression
test can assert bit-for-bit replay without a pre-refactor checkout.

Regenerate ONLY when a PR *intentionally* changes the trajectory bits
(e.g. a new key schedule) — the diff of the human-readable ``*_repr``
fields then documents the drift.  See DESIGN.md §Sharded-MC for the
platform caveat: the bits are pinned for CPU XLA; a different backend
or XLA version may legitimately re-fuse elementwise chains by a ulp, in
which case the test prints the ulp distance before failing.
"""
import json
import os
import sys

import jax
import numpy as np


GOLDEN_DIR = os.path.dirname(os.path.abspath(__file__))
# The four strategies the fixture pins (the paper's original comparison
# set).  Resolved through the repro.strategies registry like every other
# front door — prox variants share their base strategy's channel math, so
# at the fixture's 1-local-step-per-round protocol they replay the same
# bits and need no separate goldens.
STRATEGIES = ("cwfl", "cotaf", "fedavg", "decentralized")


def _check_registered():
    from repro.strategies import get_strategy
    for name in STRATEGIES:
        get_strategy(name)   # KeyError with the registry's listing if not


def workload():
    """The fixed tiny workload (shared with tests/test_goldens.py)."""
    from repro.core import TopologyConfig, make_topology
    from repro.data import (SyntheticImageConfig, make_synthetic_images,
                            partition_iid)
    from repro.models import make_mnist_mlp, nll_loss

    K = 8
    dcfg = SyntheticImageConfig.mnist_like(num_train=960, num_test=256)
    (xtr, ytr), (xte, yte) = make_synthetic_images(jax.random.PRNGKey(0),
                                                   dcfg)
    topo = make_topology(jax.random.PRNGKey(7),
                         TopologyConfig(num_clients=K, num_hotspots=3))
    xs, ys = partition_iid(jax.random.PRNGKey(1), xtr, ytr, K)
    init, apply = make_mnist_mlp(hidden=(32,))
    loss = lambda p, x, y: nll_loss(apply(p, x), y)
    return init, apply, loss, topo, xs, ys, xte, yte


def run_strategy(strategy: str):
    from repro.sim import run_rounds
    from repro.training import FLConfig

    init, apply, loss, topo, xs, ys, xte, yte = workload()
    cfg = FLConfig(strategy=strategy, rounds=4, snr_db=40.0,
                   eval_samples=256, seed=0)
    h = run_rounds(init, apply, loss, topo, xs, ys, xte, yte, cfg)
    return (np.asarray(h["train_loss"], np.float32),
            np.asarray(h["test_acc"], np.float32))


def bits(x: np.ndarray) -> list:
    return [format(v, "08x") for v in x.astype(np.float32).view(np.uint32)]


def main() -> None:
    _check_registered()
    payload = {
        "protocol": {
            "scenario": "paper-static", "rounds": 4, "clients": 8,
            "snr_db": 40.0, "seed": 0, "hidden": 32,
            "train": 960, "test": 256, "eval_samples": 256,
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            # CPU reductions tile by the host-platform device/thread
            # config, so the exact bits are pinned to the CI layout
            # (8 fake CPU devices); other configs get the ulp bound.
            "devices": len(jax.devices()),
        },
        "strategies": {},
    }
    for s in STRATEGIES:
        loss, acc = run_strategy(s)
        payload["strategies"][s] = {
            "train_loss_bits": bits(loss),
            "test_acc_bits": bits(acc),
            "train_loss_repr": [float(v) for v in loss],
            "test_acc_repr": [float(v) for v in acc],
        }
        print(f"{s:14s} loss={loss} acc={acc}")

    out = os.path.join(GOLDEN_DIR, "paper_static_T4_K8.json")
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {out}")


if __name__ == "__main__":
    sys.exit(main())
