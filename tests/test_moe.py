"""MoE dispatch: dense-reference equivalence, shard-locality, capacity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import _capacity, moe_apply, moe_init


def _dense_ref(p, x, k):
    """No-capacity dense reference: y = Σ_topk p_e · expert_e(x)."""
    logits = x @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    tp, te = jax.lax.top_k(probs, k)
    tp = tp / tp.sum(-1, keepdims=True)
    y = jnp.zeros_like(x)
    for i in range(k):
        e = te[:, i]
        g = jax.nn.silu(jnp.einsum("td,tdf->tf", x, p["w_gate"][e]))
        u = jnp.einsum("td,tdf->tf", x, p["w_up"][e])
        y += tp[:, i:i + 1] * jnp.einsum("tf,tfd->td", g * u, p["w_down"][e])
    return y


@pytest.fixture(scope="module")
def moe_params():
    return moe_init(jax.random.PRNGKey(0), 32, 64, 4, jnp.float32)


def test_matches_dense_reference_dropless(moe_params):
    x = jax.random.normal(jax.random.PRNGKey(1), (48, 32))
    y, aux = moe_apply(moe_params, x, top_k=2, capacity_factor=2.0)
    r = _dense_ref(moe_params, x, 2)
    np.testing.assert_allclose(np.asarray(y), np.asarray(r), atol=1e-5)
    assert float(aux) > 0.0


def test_shard_local_dispatch_consistency(moe_params):
    """With dropless capacity, shard-local dispatch (shards>1) must equal
    global dispatch — locality changes bookkeeping, not math."""
    x = jax.random.normal(jax.random.PRNGKey(2), (64, 32))
    y1, _ = moe_apply(moe_params, x, top_k=2, capacity_factor=2.0, shards=1)
    y4, _ = moe_apply(moe_params, x, top_k=2, capacity_factor=2.0, shards=4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y4), atol=1e-5)


def test_tiny_batch_dropless_floor(moe_params):
    """Decode batches (T ≤ 16) never drop tokens regardless of skew."""
    x = jnp.broadcast_to(jax.random.normal(jax.random.PRNGKey(3), (1, 32)),
                         (8, 32))  # identical tokens -> same experts
    y, _ = moe_apply(moe_params, x, top_k=2, capacity_factor=1.0)
    r = _dense_ref(moe_params, x, 2)
    np.testing.assert_allclose(np.asarray(y), np.asarray(r), atol=1e-5)


def test_capacity_formula():
    assert _capacity(1024, 2, 8, 1.25) == 320
    assert _capacity(2, 2, 4, 1.25) == 2        # floored at T
    assert _capacity(100, 1, 100, 1.0) == 16    # floored at min(T,16)


def test_capacity_drops_are_bounded(moe_params):
    """With cf=1.0 and adversarial skew, outputs differ from dense ref only
    on dropped tokens (never NaN, never amplified)."""
    x = jnp.broadcast_to(jax.random.normal(jax.random.PRNGKey(4), (1, 32)),
                         (64, 32))
    y, _ = moe_apply(moe_params, x, top_k=2, capacity_factor=1.0)
    assert bool(jnp.all(jnp.isfinite(y)))
    r = _dense_ref(moe_params, x, 2)
    # dropped tokens produce zeros (subset of rows); kept rows match ref
    match = jnp.all(jnp.abs(y - r) < 1e-5, axis=1)
    zero = jnp.all(jnp.abs(y) < 1e-6, axis=1)
    partial = ~match & ~zero   # one-of-two experts dropped
    assert bool(jnp.all(match | zero | partial))
    assert int(match.sum()) >= 16  # capacity floor keeps ≥16 slots
