"""Sharding-rule unit tests (mesh-shape logic only — the real 256/512-device
lowering is exercised by the dry-run; test_dist_lowering.py runs a small
subprocess version on 8 fake devices)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.dist import sharding_rules as sr
from repro.models import transformer as tfm


class FakeMesh:
    """Duck-typed mesh: axis_names + shape dict (no devices needed)."""
    def __init__(self, shape: dict):
        self.axis_names = tuple(shape)
        self.shape = dict(shape)


MESH1 = FakeMesh({"data": 16, "model": 16})
MESH2 = FakeMesh({"pod": 2, "data": 16, "model": 16})


@settings(deadline=None, max_examples=50)
@given(dim=st.integers(1, 4096), seed=st.integers(0, 3))
def test_fit_dim_always_divides(dim, seed):
    axes_opts = [("model",), ("data",), ("pod", "data"), ("data", "model")]
    axes = axes_opts[seed]
    fitted = sr._fit_dim(dim, tuple(a for a in axes if a in MESH2.shape),
                         MESH2)
    if fitted is not None:
        names = fitted if isinstance(fitted, tuple) else (fitted,)
        size = int(np.prod([MESH2.shape[a] for a in names]))
        assert dim % size == 0


def test_fit_spec_drops_pod_first():
    # 16 divisible by data(16) but not pod*data(32)
    spec = sr.fit_spec((16, 64), (sr.FSDP, "model"), MESH2)
    assert spec == P("data", "model")


def test_fit_spec_no_axis_reuse():
    # both dims want "model": second occurrence must not reuse it
    spec = sr.fit_spec((32, 32), ("model", "model"), MESH1)
    assert spec == P("model", None)


@pytest.mark.parametrize("mesh", [MESH1, MESH2], ids=["pod1", "pod2"])
@pytest.mark.parametrize("arch", ["llama3-405b", "kimi-k2-1t-a32b",
                                  "jamba-v0.1-52b", "xlstm-125m",
                                  "whisper-tiny", "gemma2-9b"])
def test_param_specs_cover_all_leaves(arch, mesh):
    cfg = get_config(arch).replace(param_dtype="bfloat16",
                                   compute_dtype="bfloat16")
    shapes = jax.eval_shape(lambda k: tfm.init_params(k, cfg),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    specs = sr.param_specs(shapes, mesh)
    flat_sh = jax.tree.leaves(shapes)
    flat_sp = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_sh) == len(flat_sp)
    for sh, sp in zip(flat_sh, flat_sp):
        for dim, part in zip(sh.shape, tuple(sp) + (None,) * 10):
            if part is None:
                continue
            names = part if isinstance(part, tuple) else (part,)
            size = int(np.prod([mesh.shape[a] for a in names]))
            assert dim % size == 0, (arch, sh.shape, sp)


def test_big_weights_are_sharded_on_both_axes():
    cfg = get_config("llama3-405b")
    shapes = jax.eval_shape(lambda k: tfm.init_params(k, cfg),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    specs = sr.param_specs(shapes, MESH1)
    wq = specs["layers"]["b0"]["attn"]["wq"]
    assert wq == P(None, "data", "model")       # (periods, d, H*hd)
    emb = specs["embed"]
    assert emb == P("model", "data")


def test_moe_expert_weights_expert_parallel():
    cfg = get_config("kimi-k2-1t-a32b")
    shapes = jax.eval_shape(lambda k: tfm.init_params(k, cfg),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    specs = sr.param_specs(shapes, MESH1)
    wg = specs["layers"]["b0"]["moe"]["w_gate"]
    assert wg == P(None, "data", None, "model")  # (periods, E, d, ff)


def test_batch_specs_fallback_batch_one():
    batch = {"tokens": jax.ShapeDtypeStruct((1, 524288), jnp.int32)}
    specs = sr.batch_specs(batch, MESH1)
    assert specs["tokens"] == P(None, None)      # batch 1 -> replicated


def test_cache_specs_head_dim_model_sharded():
    cache = {"b0": {"mixer": {
        "k": jax.ShapeDtypeStruct((4, 128, 1024, 8, 128), jnp.bfloat16),
        "v": jax.ShapeDtypeStruct((4, 128, 1024, 8, 128), jnp.bfloat16)}}}
    specs = sr.cache_specs(cache, MESH1)
    assert specs["b0"]["mixer"]["k"] == P(None, "data", None, None, "model")
