"""Per-kernel validation: shape/dtype sweeps, interpret-mode Pallas vs the
pure-jnp oracles in repro.kernels.ref (assert_allclose)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ops import flash_attention_op, ota_aggregate_op
from repro.kernels.ota_aggregate import ota_aggregate
from repro.kernels.ref import flash_attention_ref, ota_aggregate_ref
from repro.models.attention import flash_attention as model_flash


@pytest.mark.parametrize("K,C,d", [(8, 2, 512), (50, 3, 4096), (27, 4, 1000),
                                   (12, 3, 257)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ota_aggregate_matches_ref(K, C, d, dtype):
    key = jax.random.PRNGKey(0)
    s = jax.random.normal(key, (K, d), dtype)
    w = jax.random.uniform(jax.random.PRNGKey(1), (C, K), jnp.float32)
    n = 0.1 * jax.random.normal(jax.random.PRNGKey(2), (C, d), jnp.float32)
    y = ota_aggregate(s, w.astype(dtype), n.astype(dtype), tile=512)
    r = ota_aggregate_ref(s, w.astype(dtype), n.astype(dtype))
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(r, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("K,C,d,tile", [(8, 3, 1337, 256), (5, 2, 700, 512),
                                        (16, 4, 2049, 2048)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ota_aggregate_ragged_last_tile(K, C, d, tile, dtype):
    """Interpret-mode parity at non-tile-aligned d: the internally padded
    last tile must match the oracle and not leak padding into the output."""
    key = jax.random.PRNGKey(21)
    s = jax.random.normal(key, (K, d), dtype)
    w = jax.random.uniform(jax.random.PRNGKey(22), (C, K), jnp.float32)
    n = 0.1 * jax.random.normal(jax.random.PRNGKey(23), (C, d), jnp.float32)
    y = ota_aggregate(s, w.astype(dtype), n.astype(dtype), tile=tile)
    r = ota_aggregate_ref(s, w.astype(dtype), n.astype(dtype))
    assert y.shape == (C, d)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(r, np.float32), atol=tol, rtol=tol)


def test_ota_aggregate_ragged_one_hot_exact():
    """Zero noise + one-hot weights at ragged d reproduce the selected rows
    exactly, including the final (partial-tile) elements."""
    K, C, d, tile = 6, 3, 1000, 256
    s = jax.random.normal(jax.random.PRNGKey(24), (K, d))
    w = jnp.eye(K)[jnp.asarray([0, 3, 5])]
    y = ota_aggregate(s, w, jnp.zeros((C, d)), tile=tile)
    for c, k in enumerate([0, 3, 5]):
        np.testing.assert_allclose(np.asarray(y[c]), np.asarray(s[k]),
                                   atol=1e-6)


def test_ota_aggregate_linearity():
    """MAC is linear: aggregate(a+b) = aggregate(a) + aggregate(b) (no noise)."""
    key = jax.random.PRNGKey(3)
    a = jax.random.normal(key, (10, 777))
    b = jax.random.normal(jax.random.PRNGKey(4), (10, 777))
    w = jax.random.uniform(jax.random.PRNGKey(5), (3, 10))
    zero = jnp.zeros((3, 777))
    ya = ota_aggregate(a, w, zero, tile=256)
    yb = ota_aggregate(b, w, zero, tile=256)
    yab = ota_aggregate(a + b, w, zero, tile=256)
    np.testing.assert_allclose(np.asarray(ya + yb), np.asarray(yab),
                               atol=1e-4)


@pytest.mark.parametrize("B,H,KV,S,D", [(2, 4, 2, 256, 64), (1, 2, 1, 100, 32),
                                        (1, 8, 8, 130, 128), (2, 6, 2, 64, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, H, KV, S, D, dtype):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, H, S, D), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, KV, S, D), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, KV, S, D), dtype)
    o = flash_attention(q, k, v, block_q=64, block_k=64)
    r = flash_attention_ref(q, k, v)
    tol = 2e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("window,cap,causal", [(0, 0.0, True), (64, 0.0, True),
                                               (32, 50.0, True),
                                               (0, 30.0, False)])
def test_flash_attention_masking_modes(window, cap, causal):
    key = jax.random.PRNGKey(7)
    q = jax.random.normal(key, (1, 4, 192, 64))
    k = jax.random.normal(jax.random.PRNGKey(8), (1, 2, 192, 64))
    v = jax.random.normal(jax.random.PRNGKey(9), (1, 2, 192, 64))
    o = flash_attention(q, k, v, causal=causal, window=window, cap=cap,
                        block_q=64, block_k=64)
    r = flash_attention_ref(q, k, v, causal=causal, window=window, cap=cap)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5)


def test_flash_kernel_matches_model_attention():
    """The Pallas kernel and the model's chunked-jnp attention agree."""
    key = jax.random.PRNGKey(11)
    B, S, H, KV, D = 2, 96, 4, 2, 32
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(jax.random.PRNGKey(12), (B, S, KV, D))
    v = jax.random.normal(jax.random.PRNGKey(13), (B, S, KV, D))
    o_kernel = flash_attention_op(q, k, v, block_q=32, block_k=32)
    o_model = model_flash(q, k, v, causal=True, block=32)
    np.testing.assert_allclose(np.asarray(o_kernel), np.asarray(o_model),
                               atol=2e-5)


def test_ota_op_pytree_roundtrip():
    """ota_aggregate_op: pytree in, per-cluster pytree out; zero noise &
    one-hot weights reproduce the selected client's parameters."""
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (4, 5, 3)),
              "b": jax.random.normal(jax.random.PRNGKey(1), (4, 7))}
    w = jnp.eye(4)[:2]                        # clusters pick clients 0, 1
    out = ota_aggregate_op(params, w, jax.random.PRNGKey(2), 0.0)
    np.testing.assert_allclose(np.asarray(out["w"][0]),
                               np.asarray(params["w"][0]), atol=1e-6)
    np.testing.assert_allclose(np.asarray(out["b"][1]),
                               np.asarray(params["b"][1]), atol=1e-6)
