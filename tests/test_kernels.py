"""Per-kernel validation: shape/dtype sweeps, interpret-mode Pallas vs the
pure-jnp oracles in repro.kernels.ref (assert_allclose)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.cwfl_round import cwfl_round, cwfl_round_auto
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ops import flash_attention_op, ota_aggregate_op
from repro.kernels.ota_aggregate import ota_aggregate
from repro.kernels.ref import (cwfl_round_ref, flash_attention_ref,
                               ota_aggregate_ref)
from repro.models.attention import flash_attention as model_flash


def _round_inputs(K, C, d, seed=0, dtype=jnp.float32, noisy=True):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    s = jax.random.normal(ks[0], (K, d), dtype)
    a = jax.random.uniform(ks[1], (C, K), jnp.float32)
    b = jax.random.uniform(ks[2], (C, C), jnp.float32)
    m = jax.random.uniform(ks[3], (K, C), jnp.float32)
    scale = 0.1 if noisy else 0.0
    n1 = scale * jax.random.normal(ks[4], (C, d), jnp.float32)
    n2 = scale * jax.random.normal(ks[5], (C, d), jnp.float32)
    return s, a, n1, b, n2, m


# Interpret-mode Pallas runs its grid as a Python loop (~1000x the jnp
# ref per BENCH_kernels.json), so the big shapes ride the slow lane —
# the small cases keep full path coverage (multi-tile, ragged) tier-1.
@pytest.mark.parametrize("K,C,d", [
    (8, 2, 512), (12, 3, 257),
    pytest.param(50, 3, 4096, marks=pytest.mark.slow),
    pytest.param(27, 4, 1000, marks=pytest.mark.slow),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ota_aggregate_matches_ref(K, C, d, dtype):
    key = jax.random.PRNGKey(0)
    s = jax.random.normal(key, (K, d), dtype)
    w = jax.random.uniform(jax.random.PRNGKey(1), (C, K), jnp.float32)
    n = 0.1 * jax.random.normal(jax.random.PRNGKey(2), (C, d), jnp.float32)
    y = ota_aggregate(s, w.astype(dtype), n.astype(dtype), tile=512)
    r = ota_aggregate_ref(s, w.astype(dtype), n.astype(dtype))
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(r, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("K,C,d,tile", [
    (8, 3, 1337, 256), (5, 2, 700, 512),
    pytest.param(16, 4, 2049, 2048, marks=pytest.mark.slow),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ota_aggregate_ragged_last_tile(K, C, d, tile, dtype):
    """Interpret-mode parity at non-tile-aligned d: the internally padded
    last tile must match the oracle and not leak padding into the output."""
    key = jax.random.PRNGKey(21)
    s = jax.random.normal(key, (K, d), dtype)
    w = jax.random.uniform(jax.random.PRNGKey(22), (C, K), jnp.float32)
    n = 0.1 * jax.random.normal(jax.random.PRNGKey(23), (C, d), jnp.float32)
    y = ota_aggregate(s, w.astype(dtype), n.astype(dtype), tile=tile)
    r = ota_aggregate_ref(s, w.astype(dtype), n.astype(dtype))
    assert y.shape == (C, d)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(r, np.float32), atol=tol, rtol=tol)


def test_ota_aggregate_ragged_one_hot_exact():
    """Zero noise + one-hot weights at ragged d reproduce the selected rows
    exactly, including the final (partial-tile) elements."""
    K, C, d, tile = 6, 3, 1000, 256
    s = jax.random.normal(jax.random.PRNGKey(24), (K, d))
    w = jnp.eye(K)[jnp.asarray([0, 3, 5])]
    y = ota_aggregate(s, w, jnp.zeros((C, d)), tile=tile)
    for c, k in enumerate([0, 3, 5]):
        np.testing.assert_allclose(np.asarray(y[c]), np.asarray(s[k]),
                                   atol=1e-6)


def test_ota_aggregate_linearity():
    """MAC is linear: aggregate(a+b) = aggregate(a) + aggregate(b) (no noise)."""
    key = jax.random.PRNGKey(3)
    a = jax.random.normal(key, (10, 777))
    b = jax.random.normal(jax.random.PRNGKey(4), (10, 777))
    w = jax.random.uniform(jax.random.PRNGKey(5), (3, 10))
    zero = jnp.zeros((3, 777))
    ya = ota_aggregate(a, w, zero, tile=256)
    yb = ota_aggregate(b, w, zero, tile=256)
    yab = ota_aggregate(a + b, w, zero, tile=256)
    np.testing.assert_allclose(np.asarray(ya + yb), np.asarray(yab),
                               atol=1e-4)


# ---------------------------------------------------------------------------
# Fused single-pass CWFL round kernel.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("K,C,d,tile", [
    (8, 2, 2048, 512), (12, 3, 1337, 512), (5, 2, 700, 256),
    pytest.param(50, 3, 4096, 2048, marks=pytest.mark.slow),
])
def test_cwfl_round_noiseless_bitexact(K, C, d, tile):
    """Noiseless f32: the fused kernel matches the three-pass reference
    bit-for-bit, on tile-aligned and ragged d alike."""
    s, a, n1, b, n2, m = _round_inputs(K, C, d, seed=d, noisy=False)
    new, cons = cwfl_round(s, a, n1, b, n2, m, tile=tile)
    rnew, rcons = cwfl_round_ref(s, a, n1, b, n2, m)
    assert new.shape == (K, d) and cons.shape == (d,)
    np.testing.assert_array_equal(np.asarray(new), np.asarray(rnew))
    np.testing.assert_array_equal(np.asarray(cons), np.asarray(rcons))


@pytest.mark.parametrize("K,C,d,tile", [
    (8, 3, 2048, 512),
    pytest.param(27, 4, 1000, 256, marks=pytest.mark.slow),
    pytest.param(16, 4, 2049, 2048, marks=pytest.mark.slow),
])
def test_cwfl_round_injected_noise_bitexact(K, C, d, tile):
    """Fixed injected noise (both phases): still bit-for-bit vs the
    reference — the noise adds are inside the same fused pass."""
    s, a, n1, b, n2, m = _round_inputs(K, C, d, seed=3 * d, noisy=True)
    new, cons = cwfl_round(s, a, n1, b, n2, m, tile=tile)
    rnew, rcons = cwfl_round_ref(s, a, n1, b, n2, m)
    np.testing.assert_array_equal(np.asarray(new), np.asarray(rnew))
    np.testing.assert_array_equal(np.asarray(cons), np.asarray(rcons))


@pytest.mark.parametrize("d,tile", [(2048, 512), (1337, 512)])
def test_cwfl_round_bf16_signals_f32_accum(d, tile):
    """bf16 signals: accumulation stays f32 (consensus comes back f32 and
    matches the f32-computed reference to f32 tolerance; the bf16 ``new``
    matches the reference's bf16 cast exactly)."""
    K, C = 10, 3
    s, a, n1, b, n2, m = _round_inputs(K, C, d, seed=7, dtype=jnp.bfloat16)
    new, cons = cwfl_round(s, a, n1, b, n2, m, tile=tile)
    rnew, rcons = cwfl_round_ref(s, a, n1, b, n2, m)
    assert new.dtype == jnp.bfloat16 and cons.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(new, np.float32),
                                  np.asarray(rnew, np.float32))
    np.testing.assert_allclose(np.asarray(cons), np.asarray(rcons),
                               atol=1e-6, rtol=1e-6)
    # f32 accumulation: the consensus of bf16 inputs must agree with the
    # all-f32 round to bf16-rounding error only (not bf16-accumulation
    # error, which would be ~C× larger).
    s32 = s.astype(jnp.float32)
    _, cons32 = cwfl_round_ref(s32, a, n1, b, n2, m)
    np.testing.assert_allclose(np.asarray(cons), np.asarray(cons32),
                               atol=5e-2, rtol=5e-2)


@pytest.mark.parametrize("K,C", [(1, 1), (1, 2), (7, 1)])
@pytest.mark.parametrize("d", [512, 700])
def test_cwfl_round_degenerate_shapes(K, C, d):
    """K=1 / C=1 degenerate cluster layouts still match the reference
    (fp32 tolerance: 1x1 matmuls may fuse a multiply-add differently)."""
    s, a, n1, b, n2, m = _round_inputs(K, C, d, seed=K + 10 * C)
    new, cons = cwfl_round(s, a, n1, b, n2, m, tile=512)
    rnew, rcons = cwfl_round_ref(s, a, n1, b, n2, m)
    np.testing.assert_allclose(np.asarray(new), np.asarray(rnew),
                               atol=1e-6, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(cons), np.asarray(rcons),
                               atol=1e-6, rtol=1e-6)


def test_cwfl_round_auto_routes_by_dim(monkeypatch):
    """The dispatcher uses the Pallas kernel at d >= PALLAS_MIN_DIM and
    the jnp reference below (observed via spy); both agree with the
    oracle."""
    from repro.kernels import cwfl_round as cr  # the submodule

    kernel_dims = []
    real_kernel = cr.cwfl_round
    monkeypatch.setattr(
        cr, "cwfl_round",
        lambda *a, **kw: kernel_dims.append(a[0].shape[1])
        or real_kernel(*a, **kw))
    for d in (128, 4096):
        s, a, n1, b, n2, m = _round_inputs(6, 2, d, seed=d)
        new, cons = cwfl_round_auto(s, a, n1, b, n2, m)
        rnew, rcons = cwfl_round_ref(s, a, n1, b, n2, m)
        np.testing.assert_allclose(np.asarray(new), np.asarray(rnew),
                                   atol=1e-6)
        np.testing.assert_allclose(np.asarray(cons), np.asarray(rcons),
                                   atol=1e-6)
    assert kernel_dims == [4096]   # small d stayed on the jnp reference


def test_cwfl_round_guard_quarantined_cluster_bitexact():
    """Fault guard (DESIGN.md §Faults): NaN-poisoned signals plus an
    entirely quarantined cluster (its Ã row zeroed by the alive-aware
    coefficients) — the fused kernel matches the guarded reference
    bit-for-bit and both stay finite where the unguarded round NaNs."""
    K, C, d, tile = 8, 3, 1337, 512
    s, a, n1, b, n2, m = _round_inputs(K, C, d, seed=11)
    s = s.at[2].set(jnp.nan)                   # poisoned client update
    a = a.at[1].set(0.0)                       # cluster 1: zero survivors
    new, cons = cwfl_round(s, a, n1, b, n2, m, tile=tile, guard=True)
    rnew, rcons = cwfl_round_ref(s, a, n1, b, n2, m, guard=True)
    np.testing.assert_array_equal(np.asarray(new), np.asarray(rnew))
    np.testing.assert_array_equal(np.asarray(cons), np.asarray(rcons))
    assert np.isfinite(np.asarray(new)).all()
    assert np.isfinite(np.asarray(cons)).all()
    # sanity: without the guard the poison reaches every output
    unew, _ = cwfl_round_ref(s, a, n1, b, n2, m)
    assert np.isnan(np.asarray(unew)).any()


def test_cwfl_round_guard_noop_on_healthy_inputs():
    """With finite signals and no dead rows the guard's wheres are
    identities — guarded and unguarded rounds agree bit-for-bit."""
    s, a, n1, b, n2, m = _round_inputs(8, 3, 700, seed=5)
    new, cons = cwfl_round(s, a, n1, b, n2, m, tile=256)
    gnew, gcons = cwfl_round(s, a, n1, b, n2, m, tile=256, guard=True)
    np.testing.assert_array_equal(np.asarray(new), np.asarray(gnew))
    np.testing.assert_array_equal(np.asarray(cons), np.asarray(gcons))


@pytest.mark.parametrize("B,H,KV,S,D", [
    (1, 2, 1, 100, 32), (2, 6, 2, 64, 64),
    pytest.param(2, 4, 2, 256, 64, marks=pytest.mark.slow),
    pytest.param(1, 8, 8, 130, 128, marks=pytest.mark.slow),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, H, KV, S, D, dtype):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, H, S, D), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, KV, S, D), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, KV, S, D), dtype)
    o = flash_attention(q, k, v, block_q=64, block_k=64)
    r = flash_attention_ref(q, k, v)
    tol = 2e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("window,cap,causal", [(0, 0.0, True), (64, 0.0, True),
                                               (32, 50.0, True),
                                               (0, 30.0, False)])
def test_flash_attention_masking_modes(window, cap, causal):
    key = jax.random.PRNGKey(7)
    q = jax.random.normal(key, (1, 4, 192, 64))
    k = jax.random.normal(jax.random.PRNGKey(8), (1, 2, 192, 64))
    v = jax.random.normal(jax.random.PRNGKey(9), (1, 2, 192, 64))
    o = flash_attention(q, k, v, causal=causal, window=window, cap=cap,
                        block_q=64, block_k=64)
    r = flash_attention_ref(q, k, v, causal=causal, window=window, cap=cap)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5)


def test_flash_kernel_matches_model_attention():
    """The Pallas kernel and the model's chunked-jnp attention agree."""
    key = jax.random.PRNGKey(11)
    B, S, H, KV, D = 2, 96, 4, 2, 32
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(jax.random.PRNGKey(12), (B, S, KV, D))
    v = jax.random.normal(jax.random.PRNGKey(13), (B, S, KV, D))
    o_kernel = flash_attention_op(q, k, v, block_q=32, block_k=32)
    o_model = model_flash(q, k, v, causal=True, block=32)
    np.testing.assert_allclose(np.asarray(o_kernel), np.asarray(o_model),
                               atol=2e-5)


def test_ota_op_pytree_roundtrip():
    """ota_aggregate_op: pytree in, per-cluster pytree out; zero noise &
    one-hot weights reproduce the selected client's parameters."""
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (4, 5, 3)),
              "b": jax.random.normal(jax.random.PRNGKey(1), (4, 7))}
    w = jnp.eye(4)[:2]                        # clusters pick clients 0, 1
    out = ota_aggregate_op(params, w, jax.random.PRNGKey(2), 0.0)
    np.testing.assert_allclose(np.asarray(out["w"][0]),
                               np.asarray(params["w"][0]), atol=1e-6)
    np.testing.assert_allclose(np.asarray(out["b"][1]),
                               np.asarray(params["b"][1]), atol=1e-6)
