"""Small-mesh integration: the distributed train/prefill/decode steps must
lower and compile on an 8-device fake mesh (subprocess — device count must be
set before jax initializes, and the main test process keeps 1 device)."""
import json
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.launch.mesh import make_local_mesh
    from repro.models.config import InputShape
    from repro.training import dist_steps as ds
    from repro.dist.fl_integration import make_fl_plan

    mesh = make_local_mesh(4, 2)

    def flops(c):
        from repro.utils import cost_analysis_dict
        return cost_analysis_dict(c).get("flops", 0.0)

    out = {}
    for arch in %(archs)s:
        cfg = get_config(arch, reduced=True).replace(moe_shards=4)
        shape = InputShape("t", 64, 8, "train")
        plan = make_fl_plan(4, 2, jax.random.PRNGKey(0))
        fn, args, sh = ds.make_train_step(cfg, shape, mesh, plan=plan)
        with mesh:
            c = jax.jit(fn, in_shardings=ds.sr.named(sh, mesh)).lower(*args).compile()
        out[arch + ":train"] = flops(c)

        shape_d = InputShape("d", 128, 8, "decode")
        fn, args, sh = ds.make_decode_step(cfg, shape_d, mesh)
        with mesh:
            c = jax.jit(fn, in_shardings=ds.sr.named(sh, mesh)).lower(*args).compile()
        out[arch + ":decode"] = flops(c)

        shape_p = InputShape("p", 64, 8, "prefill")
        fn, args, sh, osp = ds.make_prefill_step(cfg, shape_p, mesh)
        with mesh:
            c = jax.jit(fn, in_shardings=ds.sr.named(sh, mesh),
                        out_shardings=ds.sr.named(osp, mesh)).lower(*args).compile()
        out[arch + ":prefill"] = flops(c)
    print("RESULT::" + json.dumps(out))
""")


@pytest.mark.slow
def test_dist_steps_lower_on_8_devices():
    archs = ["qwen2.5-3b", "jamba-v0.1-52b", "xlstm-125m"]
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT % {"archs": repr(archs)}],
        capture_output=True, text=True, timeout=1200,
        env={**__import__("os").environ, "PYTHONPATH": "src"})
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT::")]
    assert line, proc.stdout
    out = json.loads(line[0][len("RESULT::"):])
    assert len(out) == 9
    assert all(v > 0 for v in out.values())
