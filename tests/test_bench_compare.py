"""Bench regression gate (benchmarks/compare.py).

The CI contract: throughput ratios gate with a generous noise tolerance,
deterministic fields (modeled bytes, bitwise-parity bits) gate EXACTLY,
meta entries are skipped, and an empty intersection fails loudly instead
of vacuously passing."""
import importlib.util
import json
import os
import subprocess
import sys

import pytest

_COMPARE = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "compare.py")
spec = importlib.util.spec_from_file_location("bench_compare", _COMPARE)
bench_compare = importlib.util.module_from_spec(spec)
spec.loader.exec_module(bench_compare)

BASE = {
    "sim_scan": {"us": 100000.0, "rounds_per_sec": 60.0,
                 "compile_seconds": 5.0},
    "sim_sharded": {"traj_per_sec": 30.0, "bitwise_equal_vs_vmap": True},
    "kernel": {"us": 2000.0, "modeled_hbm_bytes": 77040000},
    "run_manifest": {"host": "a"},
    "throughput_vs_previous_file": {"sim_scan": 1.0},
}


def _mutated(**overrides):
    fresh = json.loads(json.dumps(BASE))
    for name, fields in overrides.items():
        fresh[name].update(fields)
    return fresh


def test_identical_files_green():
    r = bench_compare.compare(BASE, BASE, max_regression=0.5)
    assert r["failures"] == []
    assert r["matched"] == 3            # meta entries skipped


def test_throughput_regression_trips():
    fresh = _mutated(sim_scan={"rounds_per_sec": 20.0})   # 3x slower
    r = bench_compare.compare(BASE, fresh, max_regression=0.5)
    assert any("rounds_per_sec" in f for f in r["failures"])
    # ...but within tolerance passes.
    fresh = _mutated(sim_scan={"rounds_per_sec": 40.0})   # -33% < 50%
    assert not bench_compare.compare(BASE, fresh, 0.5)["failures"]


def test_latency_is_lower_better():
    fresh = _mutated(kernel={"us": 5000.0})               # 2.5x slower
    r = bench_compare.compare(BASE, fresh, max_regression=0.5)
    assert any("kernel.us" in f for f in r["failures"])
    fresh = _mutated(kernel={"us": 100.0})                # faster: fine
    assert not bench_compare.compare(BASE, fresh, 0.5)["failures"]


def test_exact_fields_gate_regardless_of_tolerance():
    fresh = _mutated(sim_sharded={"bitwise_equal_vs_vmap": False})
    r = bench_compare.compare(BASE, fresh, max_regression=10.0)
    assert any("bitwise_equal_vs_vmap" in f for f in r["failures"])
    fresh = _mutated(kernel={"modeled_hbm_bytes": 1})
    r = bench_compare.compare(BASE, fresh, max_regression=10.0)
    assert any("modeled_hbm_bytes" in f for f in r["failures"])


def test_compile_seconds_is_informational():
    fresh = _mutated(sim_scan={"compile_seconds": 500.0})
    assert not bench_compare.compare(BASE, fresh, 0.5)["failures"]


def test_markdown_table_marks_failures():
    fresh = _mutated(sim_scan={"rounds_per_sec": 1.0})
    r = bench_compare.compare(BASE, fresh, max_regression=0.5)
    table = bench_compare.markdown_table(r, "t")
    assert "| sim_scan | rounds_per_sec |" in table and "❌" in table


@pytest.mark.parametrize("fresh,code", [
    (BASE, 0),                                            # green
    (_mutated(sim_scan={"rounds_per_sec": 1.0}), 1),      # regression
    ({"other_bench": {"us": 1.0}}, 2),                    # no overlap
])
def test_cli_exit_codes(tmp_path, fresh, code):
    b, f = tmp_path / "base.json", tmp_path / "fresh.json"
    b.write_text(json.dumps(BASE))
    f.write_text(json.dumps(fresh))
    md = tmp_path / "delta.md"
    r = subprocess.run(
        [sys.executable, _COMPARE, str(b), str(f),
         "--max-regression", "0.5", "--markdown", str(md)],
        capture_output=True, text=True)
    assert r.returncode == code, r.stdout + r.stderr
    if code != 2:
        assert md.exists() and "Bench delta" in md.read_text()
