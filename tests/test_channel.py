"""Channel-layer invariants (eq. 4-6): water-filling, precoding, OTA MAC."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import channel as ch


@settings(deadline=None, max_examples=30)
@given(
    gains=st.lists(st.floats(0.01, 100.0), min_size=2, max_size=32),
    power=st.floats(0.5, 1e5),
)
def test_water_filling_simplex(gains, power):
    """Σ P_k = P, P_k ≥ 0 — always, for any gains (hypothesis)."""
    p = ch.water_filling(jnp.asarray(gains), power)
    assert float(jnp.min(p)) >= 0.0
    np.testing.assert_allclose(float(jnp.sum(p)), power, rtol=1e-4)


def test_water_filling_prefers_better_channels():
    g = jnp.asarray([0.1, 1.0, 10.0, 100.0])
    p = ch.water_filling(g, 4.0)
    assert float(p[3]) >= float(p[2]) >= float(p[1]) >= float(p[0])


def test_water_filling_equal_gains_equal_power():
    p = ch.water_filling(jnp.full((8,), 3.0), 16.0)
    np.testing.assert_allclose(np.asarray(p), 2.0, rtol=1e-4)


@settings(deadline=None, max_examples=30)
@given(
    gains=st.lists(st.floats(1e-3, 1e3), min_size=2, max_size=24),
    power=st.floats(0.5, 1e4),
)
def test_water_filling_monotone_in_gains(gains, power):
    """P_k = max(µ − 1/g_k, 0) is nondecreasing in g_k: a better channel
    never receives less power (property, any gains/power)."""
    g = jnp.asarray(gains)
    p = np.asarray(ch.water_filling(g, power))
    order = np.argsort(np.asarray(g))
    assert (np.diff(p[order]) >= -1e-3 * power).all()


def test_water_filling_all_tiny_gains_equal_split():
    """Degenerate branch: gains below the 1e-12 clamp make the bisection
    residual collapse — the fallback must hand back an exact equal split
    (and still sum to P)."""
    for gains in ([1e-15, 1e-14, 1e-13],
                  [0.0, 0.0, 0.0, 0.0],
                  [1e-16] * 7):
        g = jnp.asarray(gains)
        p = np.asarray(ch.water_filling(g, 12.0))
        np.testing.assert_allclose(p, 12.0 / len(gains), rtol=1e-4)
        np.testing.assert_allclose(p.sum(), 12.0, rtol=1e-4)
        assert (p >= 0).all()


@settings(deadline=None, max_examples=30)
@given(power=st.floats(0.1, 100.0), norm=st.floats(0.01, 1e4))
def test_precoding_meets_power_constraint(power, norm):
    """eq. (5): E||x||² = P^t ||θ||² ≤ P_k."""
    pt = ch.precoding_factor(jnp.asarray(power), jnp.asarray(norm))
    assert float(pt) * norm <= power * (1 + 1e-4) + 1e-6
    assert float(pt) <= power * (1 + 1e-5) + 1e-6   # float32 rounding margin


def test_ota_mac_noiseless_superposition():
    """y = Σ_k a_k s_k for masked clients, exact when σ=0 (eq. 4)."""
    key = jax.random.PRNGKey(0)
    s = jax.random.normal(key, (5, 64))
    a = jnp.asarray([1.0, 0.5, 2.0, 0.1, 3.0])
    m = jnp.asarray([1.0, 0.0, 1.0, 1.0, 0.0])
    y = ch.ota_mac(s, a, m, jax.random.PRNGKey(1), 0.0)
    expect = 1.0 * s[0] + 2.0 * s[2] + 0.1 * s[3]
    np.testing.assert_allclose(np.asarray(y), np.asarray(expect), atol=1e-5)


def test_ota_mac_noise_statistics():
    """Receiver noise has the configured std (law of large numbers)."""
    y = ch.ota_mac(jnp.zeros((1, 200000)), jnp.ones((1,)), jnp.zeros((1,)),
                   jax.random.PRNGKey(2), 0.5)
    assert abs(float(jnp.std(y)) - 0.5) < 0.01


def test_snr_db_conversion_roundtrip():
    p = 1e4
    sigma2 = ch.snr_db_to_noise_var(p, 40.0)
    np.testing.assert_allclose(10 * np.log10(p / sigma2), 40.0, rtol=1e-6)
