"""SNR K-means clustering (paper §IV)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import clustering as cl
from repro.core.topology import TopologyConfig, make_topology


@pytest.fixture(scope="module")
def topo():
    return make_topology(jax.random.PRNGKey(0),
                         TopologyConfig(num_clients=24, num_hotspots=3))


def test_cluster_plan_partition(topo):
    plan = cl.make_cluster_plan(topo.link_snr, topo.adjacency, 3,
                                jax.random.PRNGKey(1))
    # every client in exactly one cluster
    np.testing.assert_allclose(np.asarray(plan.membership.sum(0)), 1.0)
    # heads belong to their own cluster
    for c, h in enumerate(np.asarray(plan.heads)):
        assert int(plan.assignment[h]) == c
    assert float(plan.head_mask.sum()) == 3


def test_cluster_snr_positive(topo):
    plan = cl.make_cluster_plan(topo.link_snr, topo.adjacency, 3,
                                jax.random.PRNGKey(1))
    assert np.all(np.asarray(plan.cluster_snr) > 0)


def test_geometric_hotspots_recovered():
    """Clients around the same hotspot should mostly share a cluster."""
    topo = make_topology(jax.random.PRNGKey(5),
                         TopologyConfig(num_clients=30, num_hotspots=3,
                                        hotspot_std=2.0, area_size=300.0))
    plan = cl.make_cluster_plan(topo.link_snr, topo.adjacency, 3,
                                jax.random.PRNGKey(2))
    pos = np.asarray(topo.positions)
    assign = np.asarray(plan.assignment)
    # within-cluster distances should be far below global distances
    d_all, d_in = [], []
    for i in range(30):
        for j in range(i + 1, 30):
            d = np.linalg.norm(pos[i] - pos[j])
            d_all.append(d)
            if assign[i] == assign[j]:
                d_in.append(d)
    assert np.mean(d_in) < 0.6 * np.mean(d_all)


@settings(deadline=None, max_examples=20)
@given(xi=st.lists(st.floats(0.01, 1e5), min_size=2, max_size=8))
def test_consensus_weights_rows_sum_to_one(xi):
    """eq. (9): W rows sum to 1 over j≠c, diagonal 0, higher SNR ⇒ higher
    weight (hypothesis over arbitrary SNR vectors)."""
    W = np.asarray(cl.consensus_weights(jnp.asarray(xi)))
    C = len(xi)
    np.testing.assert_allclose(W.sum(axis=1), 1.0, rtol=1e-4)
    np.testing.assert_allclose(np.diag(W), 0.0, atol=1e-7)
    # monotonicity in ξ_j for a fixed receiver row
    for c in range(C):
        others = [j for j in range(C) if j != c]
        order = np.argsort([xi[j] for j in others])
        w_sorted = W[c, [others[i] for i in order]]
        assert np.all(np.diff(w_sorted) >= -1e-6)


def test_kmeans_deterministic_given_key(topo):
    p1 = cl.make_cluster_plan(topo.link_snr, topo.adjacency, 3,
                              jax.random.PRNGKey(7))
    p2 = cl.make_cluster_plan(topo.link_snr, topo.adjacency, 3,
                              jax.random.PRNGKey(7))
    np.testing.assert_array_equal(np.asarray(p1.assignment),
                                  np.asarray(p2.assignment))
