"""Baseline strategies: FedAvg, COTAF-modified, fully-decentralized."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import baselines as bl
from repro.core.topology import TopologyConfig, make_topology


@pytest.fixture(scope="module")
def topo():
    return make_topology(jax.random.PRNGKey(0),
                         TopologyConfig(num_clients=12, num_hotspots=2))


def test_fedavg_is_exact_mean():
    params = {"w": jnp.arange(12.0).reshape(4, 3)}
    new, cons = bl.fedavg_aggregate(params)
    np.testing.assert_allclose(np.asarray(cons["w"]),
                               np.asarray(params["w"].mean(0)), atol=1e-6)
    for k in range(4):
        np.testing.assert_allclose(np.asarray(new["w"][k]),
                                   np.asarray(cons["w"]), atol=1e-6)


def test_fedavg_weighted():
    params = {"w": jnp.asarray([[0.0], [1.0]])}
    _, cons = bl.fedavg_aggregate(params, weights=jnp.asarray([3.0, 1.0]))
    np.testing.assert_allclose(float(cons["w"][0]), 0.25, atol=1e-6)


def test_metropolis_doubly_stochastic_random_graphs():
    for seed in range(5):
        key = jax.random.PRNGKey(seed)
        adj = jax.random.bernoulli(key, 0.4, (10, 10))
        adj = jnp.triu(adj, 1)
        adj = adj | adj.T
        W = bl.metropolis_weights(adj)
        np.testing.assert_allclose(np.asarray(W.sum(0)), 1.0, atol=1e-5)
        np.testing.assert_allclose(np.asarray(W.sum(1)), 1.0, atol=1e-5)
        np.testing.assert_allclose(np.asarray(W), np.asarray(W.T), atol=1e-6)
        assert float(jnp.min(W)) >= 0.0


def test_metropolis_outage_pruned_graphs_with_isolated_nodes():
    """Satellite: symmetric doubly-stochastic on outage-pruned topologies,
    including isolated (degree-0) nodes, which must self-mix with weight 1
    — i.e. keep their parameters (the scenario engine models absent
    clients exactly this way)."""
    for seed in range(4):
        topo = make_topology(jax.random.PRNGKey(seed),
                             TopologyConfig(num_clients=12, num_hotspots=2,
                                            outage_snr_db=25.0))  # sparse
        adj = topo.adjacency
        # force two isolated nodes on top of whatever outage produced
        for k in (0, 7):
            adj = adj.at[k, :].set(False).at[:, k].set(False)
        W = bl.metropolis_weights(adj)
        Wn = np.asarray(W)
        np.testing.assert_allclose(Wn.sum(0), 1.0, atol=1e-5)
        np.testing.assert_allclose(Wn.sum(1), 1.0, atol=1e-5)
        np.testing.assert_allclose(Wn, Wn.T, atol=1e-6)
        assert Wn.min() >= 0.0
        assert Wn[0, 0] == pytest.approx(1.0) and Wn[7, 7] == pytest.approx(1.0)

    # a fully-isolated graph degenerates to the identity (everyone keeps
    # their params, zero effective noise)
    W = bl.metropolis_weights(jnp.zeros((6, 6), bool))
    np.testing.assert_allclose(np.asarray(W), np.eye(6), atol=1e-6)


def test_cotaf_setup_is_traceable(topo):
    """Satellite: server selection is a traced argmax (no host int() sync),
    so COTAF setup can live inside jit/scan; the traced result matches the
    eager one, and an explicit ``server`` pins the choice."""
    eager = bl.cotaf_setup(topo, jax.random.PRNGKey(0), snr_db=40.0)
    jitted = jax.jit(
        lambda: bl.cotaf_setup(topo, jax.random.PRNGKey(0), snr_db=40.0))()
    np.testing.assert_allclose(np.asarray(eager.client_power),
                               np.asarray(jitted.client_power), rtol=1e-6)
    # documented rule: server = argmax_k mean_j |h_kj|²
    expect = int(jnp.argmax((jnp.abs(topo.link_gain) ** 2).mean(axis=1)))
    pinned = bl.cotaf_setup(topo, jax.random.PRNGKey(0), snr_db=40.0,
                            server=expect)
    np.testing.assert_allclose(np.asarray(eager.client_power),
                               np.asarray(pinned.client_power), rtol=1e-6)
    other = bl.cotaf_setup(topo, jax.random.PRNGKey(0), snr_db=40.0,
                           server=(expect + 1) % topo.num_clients)
    assert not np.allclose(np.asarray(other.client_power),
                           np.asarray(eager.client_power))


def test_decentralized_consensus_converges_to_mean():
    """Iterating the noiseless mixing reaches the global average (eq. 3's
    consensus property — requires a CONNECTED graph, so disable outage)."""
    topo = make_topology(jax.random.PRNGKey(0),
                         TopologyConfig(num_clients=12, num_hotspots=2,
                                        outage_snr_db=-1000.0))
    state = bl.decentralized_setup(topo, jax.random.PRNGKey(1), snr_db=200.0)
    K = topo.num_clients
    params = {"w": jax.random.normal(jax.random.PRNGKey(2), (K, 8))}
    target = np.asarray(params["w"].mean(0))
    cur = params
    for i in range(200):
        cur, _ = bl.decentralized_aggregate(cur, state,
                                            jax.random.PRNGKey(3 + i))
    got = np.asarray(cur["w"])
    for k in range(K):
        np.testing.assert_allclose(got[k], target, atol=1e-2)


def test_cotaf_noiseless_is_weighted_mean(topo):
    state = bl.cotaf_setup(topo, jax.random.PRNGKey(1), snr_db=40.0)
    state = bl.COTAFState(client_power=state.client_power,
                          total_power=state.total_power,
                          noise_std=state.noise_std * 0.0)
    K = topo.num_clients
    params = {"w": jax.random.normal(jax.random.PRNGKey(4), (K, 8))}
    new, cons = bl.cotaf_aggregate(params, state, jax.random.PRNGKey(5),
                                   precode=False)
    p = np.sqrt(np.asarray(state.client_power) / state.total_power)
    expect = (p[:, None] * np.asarray(params["w"])).sum(0) / p.sum()
    np.testing.assert_allclose(np.asarray(cons["w"]), expect, rtol=1e-4)


def test_cotaf_all_clients_equal_after_broadcast(topo):
    state = bl.cotaf_setup(topo, jax.random.PRNGKey(1), snr_db=40.0)
    K = topo.num_clients
    params = {"w": jax.random.normal(jax.random.PRNGKey(6), (K, 8))}
    new, cons = bl.cotaf_aggregate(params, state, jax.random.PRNGKey(7))
    for k in range(K):
        np.testing.assert_allclose(np.asarray(new["w"][k]),
                                   np.asarray(cons["w"]), atol=1e-6)
