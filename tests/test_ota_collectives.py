"""repro.dist.ota_collectives: flat-vector Algorithm 1 (Pallas fast path)
must agree with the reference pytree operator, and the shard_map tree
collective must run end-to-end."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cwfl
from repro.core.topology import TopologyConfig, make_topology
from repro.dist import make_fl_plan
from repro.dist import ota_collectives as oc
from repro.launch.mesh import make_local_mesh
from repro.utils import tree_flatten_vector, tree_unflatten_vector


@pytest.fixture(scope="module")
def state():
    topo = make_topology(jax.random.PRNGKey(0),
                         TopologyConfig(num_clients=12, num_hotspots=3))
    return cwfl.setup(topo, cwfl.CWFLConfig(num_clusters=3, snr_db=40.0),
                      jax.random.PRNGKey(1))


def _noiseless(state):
    return dataclasses.replace(
        state, head_noise_std=state.head_noise_std * 0.0,
        consensus_noise_std=state.consensus_noise_std * 0.0)


@pytest.mark.parametrize("d", [300, 1000, 2048])
def test_phase1_flat_pallas_matches_ref_path(state, d):
    """The Pallas route and the jnp route are the same MAC (ragged d too)."""
    K = state.num_clients
    s = jax.random.normal(jax.random.PRNGKey(2), (K, d))
    key = jax.random.PRNGKey(3)
    y_pl = oc.phase1_ota_flat(s, state, key, use_pallas=True, tile=512)
    y_ref = oc.phase1_ota_flat(s, state, key, use_pallas=False)
    np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_ref),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("precode", [True, False])
def test_flat_aggregate_matches_pytree_operator(state, precode):
    """Noiseless: cwfl_aggregate_flat == cwfl.aggregate on the flattened
    stacked pytree (the flat path reuses the channel math verbatim)."""
    st0 = _noiseless(state)
    K = state.num_clients
    params = {"w": jax.random.normal(jax.random.PRNGKey(4), (K, 37, 5)),
              "b": jax.random.normal(jax.random.PRNGKey(5), (K, 11))}
    flat = jax.vmap(tree_flatten_vector)(params)              # (K, d)

    new_flat, cons_flat = oc.cwfl_aggregate_flat(
        flat, st0, jax.random.PRNGKey(6), precode=precode)
    new_tree, cons_tree = cwfl.aggregate(params, st0, jax.random.PRNGKey(6),
                                         precode=precode)

    ref_flat = jax.vmap(tree_flatten_vector)(new_tree)
    np.testing.assert_allclose(np.asarray(new_flat), np.asarray(ref_flat),
                               atol=1e-4, rtol=1e-4)
    template = jax.tree.map(lambda x: x[0], params)
    cons_back = tree_unflatten_vector(cons_flat, template)
    np.testing.assert_allclose(np.asarray(cons_back["b"]),
                               np.asarray(cons_tree["b"]), atol=1e-4)


def test_cwfl_aggregate_flat_routes_through_fused_round(state, monkeypatch):
    """Above PALLAS_MIN_DIM the flat aggregate runs the fused single-pass
    kernel (not the three separate matmuls) — and still matches the
    explicitly-unfused result exactly."""
    calls = {"auto": 0, "pallas": []}
    real_auto = oc.cwfl_round_auto

    def spy(*a, **kw):
        calls["auto"] += 1
        calls["pallas"].append(kw.get("use_pallas"))
        return real_auto(*a, **kw)

    monkeypatch.setattr(oc, "cwfl_round_auto", spy)
    K = state.num_clients
    s = jax.random.normal(jax.random.PRNGKey(9), (K, 2000))
    key = jax.random.PRNGKey(10)
    new_k, cons_k = oc.cwfl_aggregate_flat(s, state, key)
    assert calls["auto"] == 1 and calls["pallas"] == [None]  # d>=512: pallas
    new_r, cons_r = oc.cwfl_aggregate_flat(s, state, key, use_pallas=False)
    np.testing.assert_allclose(np.asarray(new_k), np.asarray(new_r),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(cons_k), np.asarray(cons_r),
                               atol=1e-5, rtol=1e-5)


def test_replica_train_step_uses_flat_fast_path(monkeypatch):
    """make_replica_train_step's sync round flattens once through the
    fused-round path (cwfl.aggregate flat=True -> cwfl_round_auto),
    observed at trace time via eval_shape — no compute."""
    from repro.configs import get_config
    from repro.core import cwfl as cwfl_core
    from repro.dist.fl_integration import make_fl_plan
    from repro.launch.mesh import make_local_mesh
    from repro.models.config import InputShape
    from repro.training import dist_steps as ds

    calls = []
    real_auto = cwfl_core.cwfl_round_auto
    monkeypatch.setattr(cwfl_core, "cwfl_round_auto",
                        lambda *a, **kw: calls.append(a[0].shape)
                        or real_auto(*a, **kw))

    mesh = make_local_mesh(1, 1)
    cfg = get_config("gemma2-9b", reduced=True)
    shape = InputShape("t", 16, 4, "train")
    plan = make_fl_plan(4, 2, jax.random.PRNGKey(0))
    fn, args, _ = ds.make_replica_train_step(cfg, shape, mesh, plan)
    jax.eval_shape(fn, *args)
    assert len(calls) == 1
    K, d = calls[0]
    assert K == plan.num_clients and d > 512   # flattened-once, fused route


def test_build_gradient_allreduce_single_client_identity():
    """Smoke of the full shard_map path on the 1-device mesh: a single
    noiseless client's consensus is its own value."""
    mesh = make_local_mesh(1, 1)
    plan = make_fl_plan(1, 1, jax.random.PRNGKey(0), snr_db=40.0)
    plan = dataclasses.replace(plan, noise_std=0.0)
    agg = oc.build_gradient_allreduce(mesh, plan)
    tree = {"w": jax.random.normal(jax.random.PRNGKey(7), (1, 4, 3)),
            "b": jnp.ones((1, 6))}
    out = agg(tree, jax.random.PRNGKey(8))
    for k in tree:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(tree[k]),
                                   atol=1e-5)
