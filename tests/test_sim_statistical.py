"""Statistical acceptance of the engine (slow): federation must help.

The paper's qualitative claims, checked end-to-end on the seeded tiny
workload rather than at the operator level:

* at high SNR (40 dB — effectively noiseless sync), CWFL's consensus
  model must beat a SINGLE client training locally on its own 1/K shard
  (federation pools 8x the data through the OTA sync);
* the trajectory-MEAN train loss over a 2-seed Monte-Carlo is
  non-increasing round over round, up to an SGD-noise tolerance.

Both are tolerance-based statistical checks, not bit pins — they hold
across key schedules and refactors as long as the system *learns*.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import TopologyConfig, make_topology
from repro.data import SyntheticImageConfig, make_synthetic_images, partition_iid
from repro.models import make_mnist_mlp, nll_loss
from repro.optim import sgd
from repro.sim import run_monte_carlo, run_rounds
from repro.training import FLConfig
from repro.training.local import make_local_runner

K = 8
ROUNDS = 8


@pytest.fixture(scope="module")
def setup():
    dcfg = SyntheticImageConfig.mnist_like(num_train=960, num_test=512)
    (xtr, ytr), (xte, yte) = make_synthetic_images(jax.random.PRNGKey(0),
                                                   dcfg)
    topo = make_topology(jax.random.PRNGKey(7),
                         TopologyConfig(num_clients=K, num_hotspots=3))
    xs, ys = partition_iid(jax.random.PRNGKey(1), xtr, ytr, K)
    init, apply = make_mnist_mlp(hidden=(32,))
    loss = lambda p, x, y: nll_loss(apply(p, x), y)
    return init, apply, loss, topo, xs, ys, xte, yte


def _test_loss(apply, params, x, y) -> float:
    return float(nll_loss(apply(params, x), y))


@pytest.mark.slow
def test_cwfl_beats_single_client_local_training(setup):
    """2-seed CWFL at 40 dB: mean held-out loss of the final consensus
    beats a single client running the same optimizer/steps on only its
    own shard."""
    init, apply, loss, topo, xs, ys, xte, yte = setup
    cfg = FLConfig(strategy="cwfl", rounds=ROUNDS, snr_db=40.0,
                   eval_samples=512, seed=0)

    cwfl_losses = []
    for seed in (0, 1):
        h = run_rounds(init, apply, loss, topo, xs, ys, xte, yte,
                       FLConfig(strategy="cwfl", rounds=ROUNDS,
                                snr_db=40.0, eval_samples=512, seed=seed))
        cwfl_losses.append(_test_loss(apply, h["final_params"], xte, yte))

    # Single-client baseline: client 0's shard, same optimizer, same
    # total step budget (ROUNDS sync-free rounds of local SGD).
    optimizer = sgd(cfg.lr)
    n_k = xs.shape[1]
    steps = max(cfg.local_epochs * (n_k // cfg.batch_size), 1)
    local_run = make_local_runner(loss, optimizer, cfg.batch_size, steps,
                                  cfg.mu_prox)
    local_losses = []
    for seed in (0, 1):
        key = jax.random.PRNGKey(seed)
        _, k_init, k_rounds = jax.random.split(key, 3)
        params = init(k_init)
        opt = optimizer.init(params)
        for rk in jax.random.split(k_rounds, ROUNDS):
            params, opt, _ = local_run(params, opt, xs[0], ys[0],
                                       jax.random.split(rk)[0])
        local_losses.append(_test_loss(apply, params, xte, yte))

    cwfl_mean, local_mean = np.mean(cwfl_losses), np.mean(local_losses)
    assert cwfl_mean < local_mean, (
        f"federation failed to help: CWFL test loss {cwfl_mean:.4f} vs "
        f"single-client {local_mean:.4f}")


@pytest.mark.slow
def test_trajectory_mean_loss_non_increasing(setup):
    """The 2-seed trajectory-mean train loss decays monotonically up to a
    small SGD-noise tolerance (Theorem 1's O(1/T) descent, statistically)."""
    init, apply, loss, topo, xs, ys, xte, yte = setup
    cfg = FLConfig(strategy="cwfl", rounds=ROUNDS, snr_db=40.0,
                   eval_samples=512, seed=0)
    h = run_monte_carlo(init, apply, loss, topo, xs, ys, xte, yte, cfg,
                        seeds=2)
    mean_loss = np.asarray(jnp.mean(h["train_loss"], axis=0))
    assert mean_loss.shape == (ROUNDS,)
    # minibatch SGD over 2 seeds is noisy round-to-round (rises of ~0.08
    # observed on healthy runs); the acceptance bound is that no round
    # climbs past the best-so-far by more than 0.1 nats AND the
    # trajectory ends clearly below where it started.
    running_min = np.minimum.accumulate(mean_loss)
    excess = mean_loss - running_min
    assert np.all(excess <= 0.1), (
        f"trajectory-mean loss rebounded by {excess.max():.4f} at round "
        f"{int(excess.argmax()) + 1}: {mean_loss}")
    assert mean_loss[-1] < mean_loss[0] - 0.2, (
        f"no overall descent: {mean_loss[0]:.4f} -> {mean_loss[-1]:.4f}")
