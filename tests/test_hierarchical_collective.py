"""The paper-faithful hierarchical OTA collective (shard_map, two-phase
psum) and the replica-mode train step — exercised on 8 fake devices in a
subprocess (device count must be set before jax init)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.dist import shard_map
    from repro.dist.fl_integration import (make_fl_plan,
                                           hierarchical_ota_allreduce)
    from repro.launch.mesh import make_local_mesh
    import dataclasses

    mesh = make_local_mesh(8, 1)
    K = 8
    plan = make_fl_plan(K, 3, jax.random.PRNGKey(0), snr_db=40.0)
    plan = dataclasses.replace(plan, noise_std=0.0)   # noiseless check

    x = jnp.arange(K, dtype=jnp.float32)[:, None] * jnp.ones((K, 4))

    def body(xs):
        # xs: (1, 4) local client value
        return hierarchical_ota_allreduce(xs[0], plan,
                                          jax.random.PRNGKey(1))[None]

    f = jax.jit(shard_map(body, mesh=mesh, in_specs=P("data"),
                          out_specs=P("data")))
    out = np.asarray(f(x))

    # expected: Σ_k colmean[c(k)] · A_n[c(k), k] ... phase1 weights then
    # cluster consensus (receiver-independent form)
    from repro.core import cwfl as cw
    A = np.asarray(cw.phase1_weights(plan.state))
    A = A / A.sum(1, keepdims=True)
    theta_c = A @ np.asarray(x)                         # (C, 4)
    B = plan.cluster_weights
    colmean = B.mean(0)
    expect = (colmean[:, None] * theta_c).sum(0)
    err = float(np.abs(out - expect[None]).max())
    print("RESULT::" + json.dumps({"err": err,
                                   "same_on_all": float(np.abs(out - out[0]).max())}))
""")


@pytest.mark.slow
def test_hierarchical_ota_allreduce_noiseless():
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": "src"})
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT::")]
    assert line, proc.stdout
    out = json.loads(line[0][len("RESULT::"):])
    assert out["err"] < 1e-4, out
    assert out["same_on_all"] < 1e-6, out


REPLICA_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    from repro.configs import get_config
    from repro.launch.mesh import make_local_mesh
    from repro.models.config import InputShape
    from repro.training import dist_steps as ds
    from repro.dist.fl_integration import make_fl_plan

    mesh = make_local_mesh(4, 2)
    cfg = get_config("gemma2-9b", reduced=True)
    shape = InputShape("t", 32, 8, "train")
    plan = make_fl_plan(4, 2, jax.random.PRNGKey(0))
    fn, args, sh = ds.make_replica_train_step(cfg, shape, mesh, plan,
                                              local_steps=2)
    with mesh:
        c = jax.jit(fn, in_shardings=ds.sr.named(sh, mesh)).lower(*args).compile()
    from repro.utils import cost_analysis_dict
    ca = cost_analysis_dict(c)
    print("RESULT::" + json.dumps(
        {"flops": ca.get("flops", 0.0),
         "collectives": sum(1 for l in c.as_text().splitlines()
                            if "all-reduce" in l or "all-gather" in l)}))
""")


@pytest.mark.slow
def test_replica_mode_train_step_lowers():
    """Paper-faithful replica mode (Algorithm 1 across the data axis):
    stacked per-client params + CWFL aggregation compile on a 4×2 mesh."""
    proc = subprocess.run(
        [sys.executable, "-c", REPLICA_SCRIPT],
        capture_output=True, text=True, timeout=900,
        env={**os.environ, "PYTHONPATH": "src"})
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT::")]
    assert line, proc.stdout
    out = json.loads(line[0][len("RESULT::"):])
    assert out["flops"] > 0
    assert out["collectives"] > 0   # aggregation produced real collectives
