"""End-to-end FL system behaviour (paper §V claims, scaled down for CI)."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import TopologyConfig, make_topology
from repro.data import SyntheticImageConfig, make_synthetic_images, partition_iid
from repro.models import make_mnist_mlp, make_cifar_cnn, nll_loss
from repro.training import FLConfig, run_federated

K = 12


@pytest.fixture(scope="module")
def fl_setup():
    key = jax.random.PRNGKey(0)
    cfg = SyntheticImageConfig.mnist_like(num_train=2400, num_test=600)
    (xtr, ytr), (xte, yte) = make_synthetic_images(key, cfg)
    topo = make_topology(jax.random.PRNGKey(7),
                         TopologyConfig(num_clients=K, num_hotspots=3))
    xs, ys = partition_iid(jax.random.PRNGKey(1), xtr, ytr, K)
    init, apply = make_mnist_mlp()
    loss = lambda p, x, y: nll_loss(apply(p, x), y)
    return init, apply, loss, topo, xs, ys, xte, yte


@pytest.mark.parametrize("strategy", ["cwfl", "fedavg", "cotaf",
                                      "decentralized"])
def test_strategy_runs_and_learns(fl_setup, strategy):
    init, apply, loss, topo, xs, ys, xte, yte = fl_setup
    h = run_federated(init, apply, loss, topo, xs, ys, xte, yte,
                      FLConfig(strategy=strategy, rounds=6, snr_db=40.0,
                               eval_samples=512))
    assert len(h["test_acc"]) == 6
    if strategy in ("cwfl", "fedavg"):
        assert h["test_acc"][-1] > 0.3   # learns well above chance (0.1)
    else:
        assert h["test_acc"][-1] > 0.1 - 1e-6  # runs; COTAF may be unstable


@pytest.mark.slow
def test_cwfl_tracks_fedavg(fl_setup):
    """Paper claim: CWFL ≈ server-based accuracy at high SNR."""
    init, apply, loss, topo, xs, ys, xte, yte = fl_setup
    h_cwfl = run_federated(init, apply, loss, topo, xs, ys, xte, yte,
                           FLConfig(strategy="cwfl", rounds=12, snr_db=40.0,
                                    eval_samples=512))
    h_fa = run_federated(init, apply, loss, topo, xs, ys, xte, yte,
                         FLConfig(strategy="fedavg", rounds=12,
                                  eval_samples=512))
    assert h_cwfl["test_acc"][-1] > h_fa["test_acc"][-1] - 0.12


def test_fedprox_runs(fl_setup):
    init, apply, loss, topo, xs, ys, xte, yte = fl_setup
    h = run_federated(init, apply, loss, topo, xs, ys, xte, yte,
                      FLConfig(strategy="cwfl", rounds=3, snr_db=40.0,
                               mu_prox=0.1, eval_samples=256))
    assert len(h["test_acc"]) == 3


def test_cifar_cnn_shapes():
    init, apply = make_cifar_cnn()
    p = init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, 32, 3))
    out = apply(p, x)
    assert out.shape == (4, 10)
    # log-softmax outputs: rows sum to 1 in prob space
    import numpy as np
    np.testing.assert_allclose(np.exp(np.asarray(out)).sum(-1), 1.0,
                               rtol=1e-4)
