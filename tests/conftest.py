"""Shared test config. NOTE: no XLA_FLAGS here — tests must see ONE device
(the dry-run is the only place that forces 512 placeholder devices, and it
does so in its own process)."""
import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
