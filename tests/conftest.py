"""Shared test config. NOTE: no XLA_FLAGS set here — this process runs on
whatever device count the environment provides (1 locally; CI exports
``--xla_force_host_platform_device_count=8``). The subprocess-based
lowering tests and the 512-device dry-run always set their own XLA_FLAGS
before jax initializes, so they are independent of this process.

If the real ``hypothesis`` package is unavailable (offline container), a
minimal deterministic fallback implementing the subset this suite uses
(``given``/``settings`` + integers/floats/lists strategies) is registered
before collection so the property tests still run (with plain seeded
random sampling instead of hypothesis' guided shrinking search).
"""
import functools
import inspect
import random
import sys
import types

import jax
import pytest

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    def _integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _floats(min_value, max_value, **_):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def _lists(elements, min_size=0, max_size=10, **_):
        return _Strategy(
            lambda rng: [elements.example(rng)
                         for _ in range(rng.randint(min_size, max_size))])

    def _sampled_from(seq):
        return _Strategy(lambda rng: rng.choice(list(seq)))

    def _booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def _given(**strategies):
        def deco(fn):
            sig = inspect.signature(fn)
            passthrough = [p for name, p in sig.parameters.items()
                           if name not in strategies]

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_fallback_max_examples", 20)
                rng = random.Random(fn.__qualname__)   # deterministic per test
                for _ in range(n):
                    drawn = {k: s.example(rng) for k, s in strategies.items()}
                    fn(*args, **drawn, **kwargs)

            wrapper.__signature__ = sig.replace(parameters=passthrough)
            return wrapper
        return deco

    def _settings(max_examples=20, **_):
        def deco(fn):
            fn._fallback_max_examples = max_examples
            return fn
        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.lists = _lists
    _st.sampled_from = _sampled_from
    _st.booleans = _booleans

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.__is_fallback__ = True

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
