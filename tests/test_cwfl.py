"""CWFL aggregation operator (Algorithm 1, eq. 8-9)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import cwfl
from repro.core.topology import TopologyConfig, make_topology
from repro.utils import tree_weighted_sum


@pytest.fixture(scope="module")
def setup():
    topo = make_topology(jax.random.PRNGKey(0),
                         TopologyConfig(num_clients=16, num_hotspots=3))
    state = cwfl.setup(topo, cwfl.CWFLConfig(num_clusters=3, snr_db=40.0),
                       jax.random.PRNGKey(1))
    return topo, state


def _noiseless(state):
    return cwfl.CWFLState(
        plan=state.plan, client_power=state.client_power,
        total_power=state.total_power,
        head_noise_std=state.head_noise_std * 0.0,
        consensus_noise_std=state.consensus_noise_std * 0.0,
        mix=state.mix)


def _params(key, K):
    return {"w": jax.random.normal(key, (K, 6, 4)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (K, 4))}


def test_phase1_weights_eq8(setup):
    """eq. (8): p_k = sqrt(P_k/P) for members, 1 for the head's virtual
    client; zero outside the cluster."""
    _, state = setup
    A = np.asarray(cwfl.phase1_weights(state))
    p = np.sqrt(np.asarray(state.client_power) / state.total_power)
    assign = np.asarray(state.plan.assignment)
    heads = set(np.asarray(state.plan.heads).tolist())
    for c in range(A.shape[0]):
        for k in range(A.shape[1]):
            if assign[k] != c:
                assert A[c, k] == 0.0
            elif k in heads:
                np.testing.assert_allclose(A[c, k], 1.0)
            else:
                np.testing.assert_allclose(A[c, k], p[k], rtol=1e-5)


def test_noiseless_broadcast_equality(setup):
    """After phase 3, all members of a cluster hold identical parameters."""
    _, state = setup
    K = state.num_clients
    params = _params(jax.random.PRNGKey(2), K)
    new, _ = cwfl.aggregate(params, _noiseless(state), jax.random.PRNGKey(3))
    assign = np.asarray(state.plan.assignment)
    w = np.asarray(new["w"])
    for c in range(state.num_clusters):
        idx = np.where(assign == c)[0]
        for i in idx[1:]:
            np.testing.assert_allclose(w[i], w[idx[0]], atol=1e-6)


def test_identical_params_fixed_point(setup):
    """Normalized noiseless aggregation is a projection: identical client
    params are a fixed point (convex-combination property)."""
    _, state = setup
    K = state.num_clients
    base = {"w": jax.random.normal(jax.random.PRNGKey(4), (6, 4))}
    stacked = jax.tree.map(lambda x: jnp.broadcast_to(x[None], (K,) + x.shape),
                           base)
    new, cons = cwfl.aggregate(stacked, _noiseless(state),
                               jax.random.PRNGKey(5))
    np.testing.assert_allclose(np.asarray(new["w"]), np.asarray(stacked["w"]),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(cons["w"]), np.asarray(base["w"]),
                               atol=1e-5)


def test_unnormalized_literal_equations(setup):
    """normalize=False implements eq. (8)/(9) literally: θ̃_c = Σ p_k θ_k
    (weights NOT summing to 1) and θ̄_c = Σ_j W(c,j) θ̃_j + θ̃_c."""
    _, state = setup
    K = state.num_clients
    params = _params(jax.random.PRNGKey(6), K)
    st0 = _noiseless(state)
    new, _ = cwfl.aggregate(params, st0, jax.random.PRNGKey(7),
                            normalize=False, precode=False)
    # manual computation
    A = np.asarray(cwfl.phase1_weights(state))              # (C, K)
    flat = np.asarray(params["w"]).reshape(K, -1)
    theta_t = A @ flat                                       # (C, d)
    B = np.asarray(state.mix) + np.eye(state.num_clusters)
    theta_bar = B @ theta_t
    got = np.asarray(new["w"]).reshape(K, -1)
    assign = np.asarray(state.plan.assignment)
    for k in range(K):
        np.testing.assert_allclose(got[k], theta_bar[assign[k]], rtol=2e-4,
                                   atol=1e-4)


def test_noise_floor_scales_with_snr(setup):
    """Higher SNR ⇒ lower aggregation error vs the noiseless result (the
    Q₂ → 0 behaviour of Theorem 1)."""
    topo, _ = setup
    K = topo.num_clients
    params = _params(jax.random.PRNGKey(8), K)
    errs = []
    for snr in (10.0, 30.0, 50.0):
        state = cwfl.setup(topo, cwfl.CWFLConfig(num_clusters=3, snr_db=snr),
                           jax.random.PRNGKey(1))
        new, _ = cwfl.aggregate(params, state, jax.random.PRNGKey(9))
        new0, _ = cwfl.aggregate(params, _noiseless(state),
                                 jax.random.PRNGKey(9))
        errs.append(float(jnp.mean((new["w"] - new0["w"]) ** 2)))
    assert errs[0] > errs[1] > errs[2]


@pytest.mark.parametrize("normalize,precode", [(True, True), (True, False),
                                               (False, True)])
def test_flat_fast_path_matches_per_leaf_path(setup, normalize, precode):
    """The flatten-once fast path (fused cwfl_round kernel; d >= 512 so
    Pallas engages) is bit-compatible with the per-leaf reference path —
    noiseless AND with the channel noise on (the noise stream is
    replicated per leaf)."""
    _, state = setup
    K = state.num_clients
    params = {"w": jax.random.normal(jax.random.PRNGKey(31), (K, 37, 25)),
              "b": jax.random.normal(jax.random.PRNGKey(32), (K, 411))}
    for st in (state, _noiseless(state)):
        key = jax.random.PRNGKey(33)
        new_f, cons_f = cwfl.aggregate(params, st, key, normalize, precode,
                                       flat=True)
        new_l, cons_l = cwfl.aggregate(params, st, key, normalize, precode,
                                       flat=False)
        for k in params:
            np.testing.assert_array_equal(np.asarray(new_f[k]),
                                          np.asarray(new_l[k]))
            np.testing.assert_array_equal(np.asarray(cons_f[k]),
                                          np.asarray(cons_l[k]))


def test_flat_fast_path_auto_engagement(setup, monkeypatch):
    """Default routing: f32 trees flatten through the fused round;
    non-f32 trees keep the per-leaf path (their between-phase rounding
    depends on it) unless forced."""
    _, state = setup
    K = state.num_clients
    calls = []
    real = cwfl.cwfl_round_auto
    monkeypatch.setattr(cwfl, "cwfl_round_auto",
                        lambda *a, **kw: calls.append(1) or real(*a, **kw))
    f32_tree = {"w": jax.random.normal(jax.random.PRNGKey(41), (K, 40))}
    cwfl.aggregate(f32_tree, state, jax.random.PRNGKey(42))
    assert len(calls) == 1
    bf16_tree = jax.tree.map(lambda x: x.astype(jnp.bfloat16), f32_tree)
    cwfl.aggregate(bf16_tree, state, jax.random.PRNGKey(43))
    assert len(calls) == 1          # stayed on the per-leaf path
    cwfl.aggregate(bf16_tree, state, jax.random.PRNGKey(44), flat=True)
    assert len(calls) == 2          # forced


def test_channel_uses_efficiency():
    """Paper's headline efficiency: CWFL ≪ decentralized channel uses."""
    uses = cwfl.channel_uses_per_round(50, 3)
    assert uses["cwfl"] == 3 * 2 + 3
    assert uses["decentralized"] == 50 * 49
    assert uses["cwfl"] < uses["decentralized"] / 100


@settings(deadline=None, max_examples=10)
@given(seed=st.integers(0, 10_000))
def test_aggregation_linearity(setup, seed):
    """Noiseless aggregation is linear: agg(a+b) = agg(a) + agg(b)."""
    _, state = setup
    st0 = _noiseless(state)
    K = state.num_clients
    a = _params(jax.random.PRNGKey(seed), K)
    b = _params(jax.random.PRNGKey(seed + 1), K)
    ab = jax.tree.map(jnp.add, a, b)
    k = jax.random.PRNGKey(0)
    ya, _ = cwfl.aggregate(a, st0, k, precode=False)
    yb, _ = cwfl.aggregate(b, st0, k, precode=False)
    yab, _ = cwfl.aggregate(ab, st0, k, precode=False)
    np.testing.assert_allclose(np.asarray(ya["w"] + yb["w"]),
                               np.asarray(yab["w"]), atol=1e-4)
