"""Serving-path integration: prefill + one decode step must reproduce the
full forward's last-position logits, for every assigned architecture."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.models import transformer as tfm
from repro.models.inputs import make_batch
from repro.training.serve import pad_caches

SEQ = 17


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_decode_matches_forward(arch):
    cfg = get_config(arch, reduced=True)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(jax.random.PRNGKey(1), cfg, SEQ, 2, kind="prefill")
    logits_full, _ = tfm.forward(params, batch, cfg)

    prefix = dict(batch)
    prefix["tokens"] = batch["tokens"][:, :-1]
    _, caches = tfm.prefill(params, prefix, cfg)
    prompt = prefix["tokens"].shape[1] + (
        cfg.prefix_tokens if cfg.frontend == "vision_stub" else 0)
    caches = pad_caches(caches, cfg, cache_len=prompt + 4, prompt_len=prompt)

    enc_kv = None
    if cfg.frontend == "audio_stub":
        enc_out = tfm._encode_audio(params, batch, cfg)
        enc_kv = tfm.encoder_kv(tfm._first_cross_params(params, cfg),
                                enc_out, cfg)
    dec, new_caches = tfm.decode_step(
        params, batch["tokens"][:, -1:], caches,
        jnp.asarray(prompt, jnp.int32), cfg, enc_kv=enc_kv)
    err = float(jnp.max(jnp.abs(
        logits_full[:, -1].astype(jnp.float32) -
        dec[:, 0].astype(jnp.float32))))
    assert err < 5e-3, f"{arch}: decode diverges from forward by {err}"
    assert new_caches is not None
