"""repro.sim.faults + engine fault path: processes, handoff, resume.

Three contracts pinned here (DESIGN.md §Faults):

* the fault processes are scan-legal and statistically correct
  (Markov occupancy, burst correlation, blackout countdown), and the
  divergence guard's quarantine flag catches exactly the poisoned rows;
* strategy recovery is well-defined — ``reelect_heads`` hands a crashed
  head to the surviving max-gain member and leaves geometry alone;
* a trivial ``FaultConfig`` adds ZERO traced ops (jaxpr-identical to a
  scenario with no faults field at all), and interrupted+resumed
  trajectories are BITWISE identical to uninterrupted ones — with and
  without live faults — for every registered strategy.
"""
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import TopologyConfig, clustering as cl, make_topology
from repro.sim import (FaultConfig, FaultState, Scenario, get_scenario,
                       init_faults, quarantine_mask, run_rounds, step_faults)
from repro.sim.engine import _build, make_trajectory_fn
from repro.training import FLConfig

from goldens.generate import STRATEGIES, workload

K = 8


# ---------------------------------------------------------------------------
# Fault processes.
# ---------------------------------------------------------------------------

def test_fault_config_trivial_flags():
    assert FaultConfig().is_trivial
    assert not FaultConfig(crash_prob=0.1).is_trivial
    assert not FaultConfig(burst_prob=0.1).is_trivial
    assert not FaultConfig(deep_fade_prob=0.1).is_trivial
    assert not FaultConfig(divergence_guard=True).is_trivial
    # recover/burst_frac alone do nothing without their driving process
    assert FaultConfig(recover_prob=0.9, burst_frac=0.9).is_trivial


def _scan_views(cfg, T, key):
    def body(st, k):
        st, view = step_faults(st, cfg, k)
        return st, view
    keys = jax.random.split(key, T)
    _, views = jax.lax.scan(body, init_faults(cfg, K), keys)
    return views


def test_all_off_process_keeps_everyone_up():
    views = _scan_views(FaultConfig(), 50, jax.random.PRNGKey(0))
    assert np.asarray(views.alive).min() == 1.0
    assert np.asarray(views.tx_ok).min() == 1.0
    assert np.asarray(views.deep_fade).max() == 0.0


def test_markov_crash_occupancy():
    """Long-run P(down) of the 2-state chain is p_c/(p_c+p_r)."""
    p_c, p_r = 0.3, 0.5
    views = _scan_views(FaultConfig(crash_prob=p_c, recover_prob=p_r),
                        600, jax.random.PRNGKey(1))
    alive = np.asarray(views.alive)            # (T, K)
    assert set(np.unique(alive)) <= {0.0, 1.0}
    down = 1.0 - alive[100:].mean()            # burn-in
    assert abs(down - p_c / (p_c + p_r)) < 0.05


def test_deep_fade_blackout_length_and_totality():
    """A blackout silences EVERY client for exactly its configured span."""
    views = _scan_views(
        FaultConfig(deep_fade_prob=0.2, deep_fade_rounds=3),
        400, jax.random.PRNGKey(2))
    fade = np.asarray(views.deep_fade)
    tx = np.asarray(views.tx_ok)
    assert fade.max() == 1.0                   # it does fire
    # while fading, nobody transmits; alive is untouched
    assert tx[fade > 0].max() == 0.0
    assert np.asarray(views.alive).min() == 1.0
    # contiguous fade runs are whole blackouts: multiples of 3 rounds
    # (a fresh blackout may start the round the previous one drains)
    padded = np.concatenate([[0.0], fade, [0.0]])
    starts = np.where(np.diff(padded) > 0)[0]
    ends = np.where(np.diff(padded) < 0)[0]
    lengths = (ends - starts).tolist()
    assert lengths and all(n % 3 == 0 for n in lengths) and 3 in lengths


def test_burst_dropout_is_correlated():
    """Burst hits only exist while the shared burst state is active —
    the cross-client correlation per-client i.i.d. dropout cannot have."""
    views = _scan_views(
        FaultConfig(burst_prob=0.15, burst_recover_prob=0.4,
                    burst_frac=0.6),
        400, jax.random.PRNGKey(3))
    burst = np.asarray(views.burst)
    tx = np.asarray(views.tx_ok)
    assert 0.0 < burst.mean() < 1.0
    assert tx[burst == 0].min() == 1.0         # calm rounds: nobody dropped
    assert tx[burst == 1].mean() < 0.7         # burst rounds: many dropped


def test_processes_are_jit_and_vmap_legal():
    cfg = FaultConfig(crash_prob=0.2, recover_prob=0.2, burst_prob=0.1,
                      burst_recover_prob=0.3, burst_frac=0.5,
                      deep_fade_prob=0.05, deep_fade_rounds=2)
    st = init_faults(cfg, K)
    step = jax.jit(lambda s, k: step_faults(s, cfg, k))
    keys = jax.random.split(jax.random.PRNGKey(4), 5)
    st2, view = jax.vmap(step, in_axes=(None, 0))(st, keys)
    assert isinstance(st2, FaultState) and view.alive.shape == (5, K)


# ---------------------------------------------------------------------------
# Divergence guard.
# ---------------------------------------------------------------------------

def _stack(vals):
    """K-client stack of a 2-leaf pytree with per-client scale ``vals``."""
    base = {"w": jnp.ones((K, 3, 2)), "b": jnp.ones((K, 2))}
    v = jnp.asarray(vals)[:, None]
    return {"w": base["w"] * v[..., None], "b": base["b"] * v}


def test_quarantine_flags_nonfinite_rows_only():
    s = _stack(np.ones(K))
    s["w"] = s["w"].at[2, 0, 0].set(jnp.nan)
    s["b"] = s["b"].at[5, 1].set(jnp.inf)
    q = np.asarray(quarantine_mask(s))
    expect = np.ones(K)
    expect[[2, 5]] = 0.0
    np.testing.assert_array_equal(q, expect)


def test_quarantine_power_threshold():
    vals = np.ones(K)
    vals[3] = 100.0                            # ‖θ‖²/d = 1e4
    s = _stack(vals)
    np.testing.assert_array_equal(np.asarray(quarantine_mask(s)),
                                  np.ones(K))  # limit=0: finite ⇒ healthy
    q = np.asarray(quarantine_mask(s, limit=50.0))
    assert q[3] == 0.0 and q.sum() == K - 1


# ---------------------------------------------------------------------------
# Head-failure handoff (CWFL recovery hook).
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def plan():
    topo = make_topology(jax.random.PRNGKey(0),
                         TopologyConfig(num_clients=K, num_hotspots=3))
    return (cl.make_cluster_plan(topo.link_snr, topo.adjacency, 3,
                                 jax.random.PRNGKey(1)), topo)


def test_reelect_keeps_alive_heads(plan):
    p, topo = plan
    p2 = cl.reelect_heads(p, topo.link_snr, jnp.ones((K,)))
    np.testing.assert_array_equal(np.asarray(p2.heads), np.asarray(p.heads))
    np.testing.assert_array_equal(np.asarray(p2.cluster_snr),
                                  np.asarray(p.cluster_snr))


def test_reelect_replaces_dead_head_with_surviving_max_gain(plan):
    p, topo = plan
    dead = int(p.heads[0])
    alive = jnp.ones((K,)).at[dead].set(0.0)
    p2 = cl.reelect_heads(p, topo.link_snr, alive)
    h = int(p2.heads[0])
    assert h != dead
    # stays within the cluster, is alive, and maximizes aggregate SNR
    assert int(p.assignment[h]) == 0
    members = np.where(np.asarray(p.assignment) == 0)[0]
    score = np.asarray(p.membership @ topo.link_snr.T)[0]
    live = [m for m in members if m != dead]
    assert h == max(live, key=lambda m: score[m])
    # other clusters untouched; geometry untouched
    np.testing.assert_array_equal(np.asarray(p2.heads[1:]),
                                  np.asarray(p.heads[1:]))
    np.testing.assert_array_equal(np.asarray(p2.membership),
                                  np.asarray(p.membership))
    assert float(p2.head_mask.sum()) == 3.0


def test_reelect_fully_dead_cluster_keeps_stale_head(plan):
    """A cluster with no survivors keeps its (dead) head — the
    alive-aware round coefficients zero its row so the index is inert."""
    p, topo = plan
    members = np.where(np.asarray(p.assignment) == 1)[0]
    alive = jnp.ones((K,))
    for m in members:
        alive = alive.at[int(m)].set(0.0)
    p2 = jax.jit(cl.reelect_heads)(p, topo.link_snr, alive)
    assert int(p2.heads[1]) == int(p.heads[1])


# ---------------------------------------------------------------------------
# Engine: inertness, fault runs, checkpoint/resume determinism.
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def wl():
    return workload()


def _traj_jaxpr(wl, scenario, strategy="cwfl", telemetry=False):
    init, apply, loss, topo, xs, ys, xte, yte = wl
    cfg = FLConfig(strategy=strategy, rounds=3, snr_db=40.0,
                   eval_samples=256, seed=0)
    prepare, make_body = _build(init, apply, loss, topo, xs, ys, xte, yte,
                                cfg, scenario, None, telemetry=telemetry)
    jx = str(jax.make_jaxpr(make_trajectory_fn(prepare, make_body))(0, 40.0))
    # function-object reprs embed per-process heap addresses — not ops
    return re.sub(r"0x[0-9a-f]+", "0xADDR", jx)


@pytest.mark.parametrize("telemetry", [False, True])
def test_trivial_faults_trace_zero_extra_ops(wl, telemetry):
    """Static-flag discipline: an all-off FaultConfig must be literally
    invisible in the traced computation (same contract as telemetry)."""
    base = _traj_jaxpr(wl, Scenario(), telemetry=telemetry)
    off = _traj_jaxpr(wl, Scenario(faults=FaultConfig()),
                      telemetry=telemetry)
    assert base == off
    faulty = _traj_jaxpr(
        wl, Scenario(faults=FaultConfig(crash_prob=0.1, recover_prob=0.3)),
        telemetry=telemetry)
    assert faulty != base                      # and the fault path is real


def _hist(wl, strategy, scenario=None, rounds=4, **kw):
    init, apply, loss, topo, xs, ys, xte, yte = wl
    cfg = FLConfig(strategy=strategy, rounds=rounds, snr_db=40.0,
                   eval_samples=256, seed=0)
    return run_rounds(init, apply, loss, topo, xs, ys, xte, yte, cfg,
                      scenario=scenario, **kw)


def _bits(x):
    return np.asarray(x, np.float32).view(np.uint32).tolist()


@pytest.mark.parametrize("name", ["head-failure", "flaky-clients"])
def test_fault_scenarios_fire_and_stay_finite(wl, name):
    h = _hist(wl, "cwfl", scenario=get_scenario(name), rounds=6,
              telemetry=True)
    tl = np.asarray(h["train_loss"])
    assert np.isfinite(tl).all() and np.isfinite(h["test_acc"]).all()
    ex = h["telemetry"].extras
    alive = np.asarray(ex["fault_alive"])
    assert alive.shape == (6, K)
    assert alive.min() == 0.0                  # faults actually fire @seed 0
    assert np.asarray(ex["fault_tx_ok"]).min() == 0.0
    # deterministic replay: same seed ⇒ same bits, faults included
    h2 = _hist(wl, "cwfl", scenario=get_scenario(name), rounds=6,
               telemetry=True)
    assert _bits(h["train_loss"]) == _bits(h2["train_loss"])


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_resume_is_bitwise_identical(wl, strategy, tmp_path):
    """Interrupt at round 2 of 4 (checkpoint every round), resume — the
    stitched history must equal the uninterrupted run bit-for-bit."""
    full = _hist(wl, strategy)
    part = _hist(wl, strategy, checkpoint_dir=tmp_path,
                 checkpoint_every=1, stop_after=2)
    assert np.asarray(part["train_loss"]).shape == (2,)
    res = _hist(wl, strategy, checkpoint_dir=tmp_path,
                checkpoint_every=1, resume=True)
    assert _bits(res["train_loss"]) == _bits(full["train_loss"])
    assert _bits(res["test_acc"]) == _bits(full["test_acc"])
    assert res["round"].tolist() == [1, 2, 3, 4]


@pytest.mark.parametrize("stop", [1, 3])
def test_resume_from_every_boundary(wl, stop, tmp_path):
    full = _hist(wl, "cwfl")
    _hist(wl, "cwfl", checkpoint_dir=tmp_path, checkpoint_every=1,
          stop_after=stop)
    res = _hist(wl, "cwfl", checkpoint_dir=tmp_path, checkpoint_every=1,
                resume=True)
    assert _bits(res["train_loss"]) == _bits(full["train_loss"])


def test_resume_with_live_faults_is_bitwise(wl, tmp_path):
    """FaultState rides the checkpointed carry: an interrupted run under
    an ACTIVE fault process resumes onto the same crash/burst sample
    path, so the stitched trajectory still replays bit-for-bit."""
    sc = get_scenario("flaky-clients")
    full = _hist(wl, "cwfl", scenario=sc, rounds=6)
    _hist(wl, "cwfl", scenario=sc, rounds=6, checkpoint_dir=tmp_path,
          checkpoint_every=2, stop_after=3)
    res = _hist(wl, "cwfl", scenario=sc, rounds=6, checkpoint_dir=tmp_path,
                checkpoint_every=2, resume=True)
    assert _bits(res["train_loss"]) == _bits(full["train_loss"])
    assert _bits(res["test_acc"]) == _bits(full["test_acc"])


def test_checkpoint_manifest_rejects_config_drift(wl, tmp_path):
    _hist(wl, "cwfl", checkpoint_dir=tmp_path, checkpoint_every=1,
          stop_after=1)
    with pytest.raises(ValueError, match="manifest"):
        _hist(wl, "cwfl", scenario=get_scenario("head-failure"),
              checkpoint_dir=tmp_path, checkpoint_every=1, resume=True)
    with pytest.raises(FileNotFoundError):
        _hist(wl, "cwfl", checkpoint_dir=tmp_path / "nowhere", resume=True)


def test_checkpoint_api_validation(wl, tmp_path):
    with pytest.raises(ValueError, match="checkpoint_dir"):
        _hist(wl, "cwfl", resume=True)
    with pytest.raises(ValueError, match="checkpoint_dir"):
        _hist(wl, "cwfl", stop_after=2)
    with pytest.raises(ValueError, match="loop"):
        _hist(wl, "cwfl", checkpoint_dir=tmp_path, mode="loop")


@pytest.mark.slow
@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >1 device (CI exports 8 fake devices)")
def test_client_sharded_resume_is_bitwise(wl, tmp_path):
    """The client-sharded path checkpoints the same way: an interrupted
    run resumes onto the bits of the uninterrupted CHUNKED run (identical
    compiled segments).  Against the single-scan sharded run the chunked
    one re-fuses per segment length — the same ≤2-ulp class
    tests/test_sim_sharded.py documents for batch-size fusion — so that
    comparison gets the ulp bound, not the bitwise pin."""
    base = _hist(wl, "cwfl", shard="clients",
                 checkpoint_dir=tmp_path / "base", checkpoint_every=1)
    _hist(wl, "cwfl", shard="clients", checkpoint_dir=tmp_path / "crash",
          checkpoint_every=1, stop_after=2)
    res = _hist(wl, "cwfl", shard="clients",
                checkpoint_dir=tmp_path / "crash",
                checkpoint_every=1, resume=True)
    assert _bits(res["train_loss"]) == _bits(base["train_loss"])
    assert _bits(res["test_acc"]) == _bits(base["test_acc"])
    full = _hist(wl, "cwfl", shard="clients")
    ia = np.asarray(res["train_loss"], np.float32).view(np.int32)
    ib = np.asarray(full["train_loss"], np.float32).view(np.int32)
    assert int(np.max(np.abs(ia.astype(np.int64) - ib))) <= 2
    assert _bits(res["test_acc"]) == _bits(full["test_acc"])
