"""Device-parallel scenario execution (repro.sim.sharded).

Parity contract (DESIGN.md §Sharded-MC): the sharded sweep runs the SAME
traced trajectory body as the vmap sweep; the only thing the mesh
changes is the batch size XLA compiles for (global N vs per-device N/n),
and batch-size-dependent elementwise fusion can differ by ≤1 ulp per
round, compounding through SGD (the same class the engine documents for
``unroll=2``/eager ``prepare``).  Pinned here as: train-loss histories
within 2 ulp at T=2 (in practice bitwise for most strategies — COTAF's
precode chain is the one observed to re-fuse), accuracy histories
bitwise, shapes/grids identical.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import TopologyConfig, make_topology
from repro.data import SyntheticImageConfig, make_synthetic_images, partition_iid
from repro.dist.sharding_rules import client_specs, trajectory_specs
from repro.models import make_mnist_mlp, nll_loss
from repro.sim import get_scenario, run_monte_carlo, run_rounds
from repro.sim.engine import _build, make_trajectory_fn
from repro.sim.scenarios import Scenario
from repro.sim.sharded import monte_carlo_sharded
from repro.training import FLConfig

K = 8
TCFG = TopologyConfig(num_clients=K, num_hotspots=3)

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >1 device (CI: XLA_FLAGS=--xla_force_host_platform_"
           "device_count=8)")


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    dcfg = SyntheticImageConfig.mnist_like(num_train=960, num_test=256)
    (xtr, ytr), (xte, yte) = make_synthetic_images(key, dcfg)
    topo = make_topology(jax.random.PRNGKey(7), TCFG)
    xs, ys = partition_iid(jax.random.PRNGKey(1), xtr, ytr, K)
    init, apply = make_mnist_mlp(hidden=(32,))
    loss = lambda p, x, y: nll_loss(apply(p, x), y)
    return init, apply, loss, topo, xs, ys, xte, yte


def _mc(setup, cfg, **kw):
    init, apply, loss, topo, xs, ys, xte, yte = setup
    return run_monte_carlo(init, apply, loss, topo, xs, ys, xte, yte, cfg,
                           **kw)


def _max_ulp(a, b) -> int:
    ia = np.asarray(a, np.float32).view(np.int32).astype(np.int64)
    ib = np.asarray(b, np.float32).view(np.int32).astype(np.int64)
    return int(np.max(np.abs(ia - ib)))


def _assert_sweep_parity(h_v, h_s, max_ulp: int = 2):
    """The documented sharded==vmap bound: losses within ``max_ulp``
    (bitwise in most cases), accuracies bitwise."""
    ulp = _max_ulp(h_v["train_loss"], h_s["train_loss"])
    assert ulp <= max_ulp, f"train_loss off by {ulp} ulp"
    assert bool(jnp.array_equal(h_v["test_acc"], h_s["test_acc"]))


# ---------------------------------------------------------------------------
# Trajectory-parallel Monte-Carlo (shard="mc").
# ---------------------------------------------------------------------------

@multi_device
def test_sharded_seeds_sweep_matches_vmap_cwfl(setup):
    """Acceptance: the seeds-only sharded sweep reproduces the
    single-device vmap path (within the documented ulp bound; observed
    bitwise for CWFL on CPU)."""
    cfg = FLConfig(strategy="cwfl", rounds=2, snr_db=40.0,
                   eval_samples=256, seed=0)
    h_v = _mc(setup, cfg, seeds=8)
    h_s = _mc(setup, cfg, seeds=8, shard="mc")
    assert h_s["train_loss"].shape == (8, 2)
    _assert_sweep_parity(h_v, h_s)


@pytest.mark.slow
@pytest.mark.parametrize("strategy", ["cotaf", "fedavg", "decentralized"])
@multi_device
def test_sharded_seeds_sweep_matches_vmap_baselines(setup, strategy):
    cfg = FLConfig(strategy=strategy, rounds=2, snr_db=40.0,
                   eval_samples=256, seed=0)
    h_v = _mc(setup, cfg, seeds=8)
    h_s = _mc(setup, cfg, seeds=8, shard="mc")
    _assert_sweep_parity(h_v, h_s)


@pytest.mark.slow
@multi_device
def test_sharded_grid_sweep_matches_flattened_vmap(setup):
    """The mesh itself adds nothing: the sharded flattened grid equals a
    ONE-device vmap over the same flattened pairs (observed bitwise; only
    per-device batch-size fusion can split them, bounded at 2 ulp).  The
    standard run_monte_carlo grid path batches nested instead — that gap
    is a vmap-structure property, covered by the tolerance test below."""
    init, apply, loss, topo, xs, ys, xte, yte = setup
    cfg = FLConfig(strategy="cwfl", rounds=2, eval_samples=256, seed=0)
    grid = (0.0, 20.0, 40.0)
    prepare, make_body = _build(init, apply, loss, topo, xs, ys, xte, yte,
                                cfg, Scenario(), None)
    traj = make_trajectory_fn(prepare, make_body)
    seeds = jnp.arange(2)
    sf = jnp.repeat(seeds, 3)
    gf = jnp.tile(jnp.asarray(grid, jnp.float32), 2)
    l_flat, a_flat = jax.jit(jax.vmap(traj))(sf, gf)
    l_sh, a_sh, _ = monte_carlo_sharded(traj, seeds, grid, None, 2)
    assert l_sh.shape == (2, 3, 2)
    assert _max_ulp(l_sh.reshape(6, 2), l_flat) <= 2
    assert bool(jnp.array_equal(a_sh.reshape(6, 2), a_flat))


@pytest.mark.slow
@multi_device
def test_sharded_snr_grid_matches_vmap_ulp(setup):
    """Against the standard nested-vmap grid path: ulp-level agreement
    (flattening changes XLA batching by ~1 ulp/round, compounding through
    SGD — DESIGN.md §Sharded-MC), with identical shapes and grids."""
    cfg = FLConfig(strategy="cwfl", rounds=2, eval_samples=256, seed=0)
    sc = get_scenario("snr-sweep")
    h_v = _mc(setup, cfg, scenario=sc, seeds=2)
    h_s = _mc(setup, cfg, scenario=sc, seeds=2, shard="mc")
    assert h_s["train_loss"].shape == h_v["train_loss"].shape == (2, 5, 2)
    np.testing.assert_allclose(np.asarray(h_s["train_loss"]),
                               np.asarray(h_v["train_loss"]),
                               rtol=2e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_s["test_acc"]),
                               np.asarray(h_v["test_acc"]), atol=1e-2)


@multi_device
def test_sharded_padding_non_divisible(setup):
    """3 seeds on an 8-way mesh: the grid pads to the device count and the
    padded trajectories are sliced off — results still match vmap."""
    cfg = FLConfig(strategy="cwfl", rounds=2, snr_db=40.0,
                   eval_samples=256, seed=5)
    h_v = _mc(setup, cfg, seeds=3)
    h_s = _mc(setup, cfg, seeds=3, shard="mc")
    assert h_s["train_loss"].shape == (3, 2)
    _assert_sweep_parity(h_v, h_s)


def test_bad_shard_names(setup):
    cfg = FLConfig(strategy="cwfl", rounds=1, eval_samples=64)
    init, apply, loss, topo, xs, ys, xte, yte = setup
    with pytest.raises(ValueError, match="shard='mc'"):
        run_monte_carlo(init, apply, loss, topo, xs, ys, xte, yte, cfg,
                        seeds=2, shard="clients")
    with pytest.raises(ValueError, match="shard='clients'"):
        run_rounds(init, apply, loss, topo, xs, ys, xte, yte, cfg,
                   shard="mc")


# ---------------------------------------------------------------------------
# Client-parallel trajectory (shard="clients").
# ---------------------------------------------------------------------------

@multi_device
def test_client_sharded_matches_unsharded(setup):
    """Splitting the K-client axis over the mesh reproduces the unsharded
    trajectory: metrics to psum-reassociation tolerance (the per-cluster
    OTA sums ride the collective), final params within a few ulp."""
    init, apply, loss, topo, xs, ys, xte, yte = setup
    cfg = FLConfig(strategy="cwfl", rounds=3, snr_db=40.0,
                   eval_samples=256, seed=3)
    h_u = run_rounds(init, apply, loss, topo, xs, ys, xte, yte, cfg)
    h_c = run_rounds(init, apply, loss, topo, xs, ys, xte, yte, cfg,
                     shard="clients")
    np.testing.assert_allclose(np.asarray(h_c["train_loss"]),
                               np.asarray(h_u["train_loss"]),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(h_c["test_acc"]),
                               np.asarray(h_u["test_acc"]), atol=1e-2)
    for a, b in zip(jax.tree.leaves(h_c["final_params"]),
                    jax.tree.leaves(h_u["final_params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_client_sharded_guards(setup):
    from repro.sim import ChannelProcessConfig
    init, apply, loss, topo, xs, ys, xte, yte = setup
    cfg = FLConfig(strategy="cotaf", rounds=1, eval_samples=64)
    # capability-flag gate names the strategy's class, not a hard-coded
    # name check
    with pytest.raises(NotImplementedError, match="COTAFStrategy"):
        run_rounds(init, apply, loss, topo, xs, ys, xte, yte, cfg,
                   shard="clients")
    cfg = FLConfig(strategy="cwfl", rounds=1, eval_samples=64)
    sc = Scenario(name="csi", channel=ChannelProcessConfig(csi_error_std=0.3))
    with pytest.raises(NotImplementedError, match="static"):
        run_rounds(init, apply, loss, topo, xs, ys, xte, yte, cfg,
                   scenario=sc, shard="clients")
    # live-progress / loop mode would be silently dead on the sharded
    # path — must refuse loudly instead
    with pytest.raises(ValueError, match="progress"):
        run_rounds(init, apply, loss, topo, xs, ys, xte, yte, cfg,
                   shard="clients", progress=lambda *a: None)


# ---------------------------------------------------------------------------
# Sharding-rules / mesh helpers (run on any device count).
# ---------------------------------------------------------------------------

def test_trajectory_and_client_specs():
    from repro.launch.mesh import make_client_mesh, make_mc_mesh
    n = len(jax.devices())
    mesh = make_mc_mesh()
    sh = {"m": jax.ShapeDtypeStruct((n * 3, 7), jnp.float32),
          "odd": jax.ShapeDtypeStruct((n * 2 + 1,), jnp.float32)}
    specs = trajectory_specs(sh, mesh)
    assert specs["m"] == P("mc", None)
    # non-divisible leading dim falls back to replication (fit rule)
    assert specs["odd"] == (P("mc") if n == 1 else P(None))

    cmesh = make_client_mesh()
    cs = client_specs({"w": jax.ShapeDtypeStruct((n * 4, 5), jnp.float32)},
                      cmesh)
    assert cs["w"] == P("clients", None)


def test_mesh_device_cap_errors():
    from repro.launch.mesh import make_mc_mesh
    with pytest.raises(ValueError, match="devices"):
        make_mc_mesh(len(jax.devices()) + 1)
