"""Deliverable (f): per-architecture smoke tests — a REDUCED variant of each
assigned architecture runs one forward and one train step on CPU with the
right output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.models import transformer as tfm
from repro.models.inputs import make_batch
from repro.optim import sgd
from repro.training.steps import make_train_step

SEQ = 32
BATCH = 2


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_reduced_forward_shapes_and_finite(arch):
    cfg = get_config(arch, reduced=True)
    assert cfg.num_layers <= 2 * max(len(cfg.pattern), 1)
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(jax.random.PRNGKey(1), cfg, SEQ, BATCH, kind="train")
    logits, aux = jax.jit(lambda p, b: tfm.forward(p, b, cfg))(params, batch)
    total = SEQ if cfg.frontend != "vision_stub" else SEQ
    assert logits.shape == (BATCH, total, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_reduced_train_step(arch):
    cfg = get_config(arch, reduced=True)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    opt = sgd(1e-3)
    step = jax.jit(make_train_step(cfg, opt))
    opt_state = opt.init(params)
    batch = make_batch(jax.random.PRNGKey(1), cfg, SEQ, BATCH, kind="train")
    new_params, opt_state, metrics = step(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    # at least one parameter changed
    changed = jax.tree.map(
        lambda a, b: bool(jnp.any(a != b)), params, new_params)
    assert any(jax.tree.leaves(changed))
    # loss decreases on repeated steps over the same batch
    p, s = new_params, opt_state
    first = float(metrics["loss"])
    for _ in range(3):
        p, s, metrics = step(p, s, batch)
    assert float(metrics["loss"]) <= first + 1e-3


@pytest.mark.parametrize("arch", ["qwen3-moe-235b-a22b", "jamba-v0.1-52b",
                                  "xlstm-125m", "gemma2-9b", "whisper-tiny"])
def test_reduced_prefill_cache_structure(arch):
    cfg = get_config(arch, reduced=True)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(jax.random.PRNGKey(1), cfg, SEQ, BATCH, kind="prefill")
    logits, caches = tfm.prefill(params, batch, cfg)
    assert logits.shape[0] == BATCH and logits.shape[1] == 1
    assert caches is not None
    # every pattern position contributes a cache with a leading period axis
    for i in range(len(cfg.pattern)):
        leaves = jax.tree.leaves(caches[f"b{i}"])
        assert all(l.shape[0] == cfg.num_periods for l in leaves)
