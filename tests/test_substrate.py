"""Substrate tests: data partitioners, optimizers, checkpointing, pytree
utils, serving glue."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.data import (SyntheticImageConfig, make_synthetic_images,
                        make_token_dataset, partition_iid, partition_noniid)
from repro.data.synthetic import label_histogram
from repro.optim import adamw, cosine_schedule, inverse_time_schedule, sgd, sgd_momentum
from repro.training.serve import _ring_order
from repro.utils import (tree_flatten_vector, tree_unflatten_vector,
                         tree_sq_norm)


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_synthetic_images_learnable_shapes():
    cfg = SyntheticImageConfig.mnist_like(num_train=2000, num_test=500)
    (xtr, ytr), (xte, yte) = make_synthetic_images(jax.random.PRNGKey(0), cfg)
    assert xtr.shape == (2000, 28, 28, 1) and yte.shape == (500,)
    assert int(ytr.min()) >= 0 and int(ytr.max()) <= 9


def test_partition_iid_shapes_and_coverage():
    x = jnp.arange(100.0)[:, None]
    y = (jnp.arange(100) % 10).astype(jnp.int32)
    xs, ys = partition_iid(jax.random.PRNGKey(0), x, y, 10)
    assert xs.shape == (10, 10, 1)
    # all samples used exactly once
    assert len(set(np.asarray(xs).ravel().tolist())) == 100


def test_partition_noniid_label_concentration():
    """Paper §V: each client sees few classes after label-sorted sharding."""
    n = 2000
    y = (jnp.arange(n) % 10).astype(jnp.int32)
    x = jax.random.normal(jax.random.PRNGKey(1), (n, 4))
    xs, ys = partition_noniid(jax.random.PRNGKey(2), x, y, num_clients=20,
                              shards_per_client=4, num_shards=200)
    hist = label_histogram(ys, 10)
    classes_per_client = (hist > 0).sum(axis=1)
    assert classes_per_client.max() <= 5   # ≤ shards_per_client (+ boundary)
    iid_xs, iid_ys = partition_iid(jax.random.PRNGKey(3), x, y, 20)
    iid_hist = label_histogram(iid_ys, 10)
    assert (iid_hist > 0).sum(axis=1).min() >= 8


def test_token_dataset_markov_structure():
    toks = make_token_dataset(jax.random.PRNGKey(0), vocab_size=64,
                              num_sequences=8, seq_len=100, branching=4)
    assert toks.shape == (8, 101)
    assert int(toks.max()) < 64 and int(toks.min()) >= 0


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def _quad_min(opt, steps=200):
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(steps):
        grads = jax.tree.map(lambda p: 2 * p, params)   # f = ||x||²
        updates, state = opt.update(grads, state, params)
        params = jax.tree.map(jnp.add, params, updates)
    return float(jnp.sum(params["x"] ** 2))


def test_sgd_minimizes_quadratic():
    assert _quad_min(sgd(0.1)) < 1e-6


def test_momentum_minimizes_quadratic():
    assert _quad_min(sgd_momentum(0.05, 0.9)) < 1e-6


def test_adamw_minimizes_quadratic():
    assert _quad_min(adamw(0.1)) < 1e-3


def test_inverse_time_schedule_matches_theorem():
    sched = inverse_time_schedule(mu=2.0, gamma=10.0)
    np.testing.assert_allclose(float(sched(jnp.asarray(0.0))), 2 / (2 * 10))
    np.testing.assert_allclose(float(sched(jnp.asarray(10.0))), 2 / (2 * 20))


def test_cosine_schedule_endpoints():
    sched = cosine_schedule(1.0, 100, warmup=10)
    assert float(sched(jnp.asarray(0))) == 0.0
    np.testing.assert_allclose(float(sched(jnp.asarray(10))), 1.0, rtol=1e-5)
    assert float(sched(jnp.asarray(100))) < 1e-6


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.asarray([1, 2, 3], jnp.int32)}}
    save_checkpoint(tmp_path, 7, tree)
    save_checkpoint(tmp_path, 12, jax.tree.map(lambda x: x + 1, tree))
    assert latest_step(tmp_path) == 12
    out = load_checkpoint(tmp_path, tree)            # loads latest
    np.testing.assert_allclose(np.asarray(out["a"]),
                               np.asarray(tree["a"] + 1))
    out7 = load_checkpoint(tmp_path, tree, step=7)
    np.testing.assert_allclose(np.asarray(out7["b"]["c"]),
                               np.asarray(tree["b"]["c"]))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save_checkpoint(tmp_path, 1, {"a": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        load_checkpoint(tmp_path, {"a": jnp.zeros((3, 3))})


# ---------------------------------------------------------------------------
# pytree utils + serving glue
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=20)
@given(seed=st.integers(0, 1000))
def test_flatten_roundtrip(seed):
    key = jax.random.PRNGKey(seed)
    tree = {"w": jax.random.normal(key, (3, 4)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (7,)),
            "n": {"s": jax.random.normal(jax.random.fold_in(key, 2), (2, 2, 2))}}
    vec = tree_flatten_vector(tree)
    assert vec.shape == (3 * 4 + 7 + 8,)
    back = tree_unflatten_vector(vec, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


@settings(deadline=None, max_examples=30)
@given(S=st.integers(1, 300), W=st.integers(1, 64))
def test_ring_order_property(S, W):
    """Ring slot j holds the newest position p ≤ S-1 with p ≡ j (mod W)."""
    idx = _ring_order(S, W)
    for j, p in enumerate(idx):
        assert p % W == j % W or p < 0
        assert p <= S - 1
        assert p > S - 1 - W
