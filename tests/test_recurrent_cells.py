"""Recurrent mixers: chunkwise-parallel forms must equal the step-by-step
recurrences (mLSTM), and chunked selective scan must equal a sequential
reference (Mamba)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ArchConfig, LayerSpec
from repro.models.ssm import mamba_apply, mamba_init, selective_scan, _ssm_coeffs
from repro.models.xlstm import (mlstm_cell, mlstm_step, slstm_apply,
                                slstm_init, mlstm_init, mlstm_apply)


def test_mlstm_chunkwise_equals_recurrence():
    B, S, nh, dh = 2, 37, 3, 8
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, S, nh, dh))
    k = jax.random.normal(ks[1], (B, S, nh, dh))
    v = jax.random.normal(ks[2], (B, S, nh, dh))
    i_raw = jax.random.normal(ks[3], (B, S, nh))
    f_raw = jax.random.normal(ks[4], (B, S, nh)) + 2.0

    h_chunk, state_chunk = mlstm_cell(q, k, v, i_raw, f_raw, chunk=8)

    # step-by-step oracle
    state = (jnp.zeros((B, nh, dh, dh)), jnp.zeros((B, nh, dh)),
             jnp.full((B, nh), -1e30))
    hs = []
    for t in range(S):
        h_t, state = mlstm_step(q[:, t], k[:, t], v[:, t], i_raw[:, t],
                                f_raw[:, t], state)
        hs.append(h_t)
    h_ref = jnp.stack(hs, axis=1)
    np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(h_ref),
                               atol=2e-4, rtol=2e-3)
    # final states agree
    np.testing.assert_allclose(np.asarray(state_chunk[0]),
                               np.asarray(state[0]), atol=2e-4, rtol=2e-3)


def test_mlstm_chunk_size_invariance():
    B, S, nh, dh = 1, 64, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    q, k, v = (jax.random.normal(ks[i], (B, S, nh, dh)) for i in range(3))
    i_raw = jax.random.normal(ks[3], (B, S, nh))
    f_raw = jax.random.normal(ks[4], (B, S, nh)) + 1.0
    h8, _ = mlstm_cell(q, k, v, i_raw, f_raw, chunk=8)
    h64, _ = mlstm_cell(q, k, v, i_raw, f_raw, chunk=64)
    h13, _ = mlstm_cell(q, k, v, i_raw, f_raw, chunk=13)  # ragged chunks
    np.testing.assert_allclose(np.asarray(h8), np.asarray(h64), atol=2e-4,
                               rtol=2e-3)
    np.testing.assert_allclose(np.asarray(h13), np.asarray(h64), atol=2e-4,
                               rtol=2e-3)


def _mamba_cfg(d_model=32, chunk=8):
    return ArchConfig(name="t", arch_type="ssm", num_layers=1, d_model=d_model,
                      num_heads=2, num_kv_heads=2, d_ff=0, vocab_size=64,
                      pattern=(LayerSpec(mixer="mamba", ffn="none"),),
                      ssm_chunk=chunk)


def test_selective_scan_sequential_reference():
    cfg = _mamba_cfg()
    p = mamba_init(jax.random.PRNGKey(0), cfg.d_model, cfg.d_inner,
                   cfg.ssm_state, cfg.ssm_conv, cfg.dt_rank, jnp.float32)
    xz = jax.random.normal(jax.random.PRNGKey(1), (2, 21, cfg.d_inner))
    y, h = selective_scan(p, xz, cfg.ssm_state, cfg.dt_rank, chunk=8)

    # sequential oracle
    dA, dBu, Cc = _ssm_coeffs(p, xz, cfg.ssm_state, cfg.dt_rank)
    hh = jnp.zeros((2, cfg.d_inner, cfg.ssm_state))
    ys = []
    for t in range(21):
        hh = dA[:, t] * hh + dBu[:, t]
        ys.append(jnp.einsum("bdn,bn->bd", hh, Cc[:, t])
                  + p["D"] * xz[:, t])
    y_ref = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hh), atol=1e-4,
                               rtol=1e-3)


def test_mamba_chunk_invariance_with_padding():
    cfg8 = _mamba_cfg(chunk=8)
    cfg64 = _mamba_cfg(chunk=64)
    p = mamba_init(jax.random.PRNGKey(0), 32, cfg8.d_inner, cfg8.ssm_state,
                   cfg8.ssm_conv, cfg8.dt_rank, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 19, 32))
    y8, c8 = mamba_apply(p, x, cfg8)
    y64, c64 = mamba_apply(p, x, cfg64)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y64), atol=1e-4,
                               rtol=1e-3)
    # carried state must not be decayed by padding (identity transitions)
    np.testing.assert_allclose(np.asarray(c8["h"]), np.asarray(c64["h"]),
                               atol=1e-4, rtol=1e-3)


def test_slstm_streaming_consistency():
    cfg = ArchConfig(name="t", arch_type="ssm", num_layers=1, d_model=32,
                     num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=64,
                     pattern=(LayerSpec(mixer="slstm", ffn="none"),))
    p = slstm_init(jax.random.PRNGKey(0), 32, 4, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 32))
    y_full, _ = slstm_apply(p, x, cfg)
    y1, cache = slstm_apply(p, x[:, :11], cfg)
    y2, _ = slstm_apply(p, x[:, 11:], cfg, cache=cache)
    np.testing.assert_allclose(np.asarray(y_full[:, :11]), np.asarray(y1),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(y_full[:, 11:]), np.asarray(y2),
                               atol=1e-5)


def test_mlstm_block_streaming_consistency():
    cfg = ArchConfig(name="t", arch_type="ssm", num_layers=1, d_model=32,
                     num_heads=2, num_kv_heads=2, d_ff=0, vocab_size=64,
                     pattern=(LayerSpec(mixer="mlstm", ffn="none"),),
                     mlstm_chunk=8)
    p = mlstm_init(jax.random.PRNGKey(0), 32, 2, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 32))
    y_full, _ = mlstm_apply(p, x, cfg)
    y1, cache = mlstm_apply(p, x[:, :16], cfg)
    y2, _ = mlstm_apply(p, x[:, 16:17], cfg, cache=cache)
    np.testing.assert_allclose(np.asarray(y_full[:, :16]), np.asarray(y1),
                               atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(y_full[:, 16]), np.asarray(y2[:, 0]),
                               atol=2e-4, rtol=2e-3)
