"""Live streaming + alert monitor (repro.obs.stream / .monitor,
DESIGN.md §Obs-live).

The load-bearing contracts, in order of blast radius:

* **stream-off is free**: with ``stream=None`` the telemetry build's
  traced jaxpr is byte-identical to the pre-stream build — the tap is a
  STATIC opt-in, exactly like telemetry itself;
* **stream-on is bit-neutral**: the tapped run's ``train_loss``/
  ``test_acc`` history is bit-for-bit the untapped run's (the
  single-trajectory tap only *reads* the round's already-materialized
  outputs; the Monte-Carlo tap fires post-scan on the stacked output
  buffers — an in-body tap under ``vmap`` re-fuses the batched loss
  reduction and costs 1 ulp, see DESIGN.md §Obs-live);
* **the stream IS the telemetry**: every drained record equals the
  post-hoc ``history["telemetry"]`` slice bitwise, for all four
  strategies and on every executor (scan, vmap MC, mc-sharded rank-0,
  client-sharded), and a checkpoint-resumed run continues absolute
  round numbers and cumulative ledgers seamlessly;
* the `Monitor` rules fire on synthetic violations, stay silent on
  healthy runs, and ``abort_on_alert`` checkpoint-then-stops a run that
  remains resumable.
"""
import json
import os
import re
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from goldens.generate import STRATEGIES, workload
from repro.core import TopologyConfig
from repro.obs import (ConsensusDriftRule, ConvergenceStallRule,
                       JsonlStreamSink, MemorySink, Monitor,
                       NonFiniteLossRule, PowerBudgetRule, PrometheusSink,
                       QuarantineRateRule, RoundStream, default_rules)
from repro.obs.stream import _np_tree, _tree_index
from repro.sim import run_monte_carlo, run_rounds
from repro.training import FLConfig

K = 8
TCFG = TopologyConfig(num_clients=K, num_hotspots=3)

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >1 device (CI: XLA_FLAGS=--xla_force_host_platform_"
           "device_count=8)")


@pytest.fixture(scope="module")
def wl():
    return workload()


def _cfg(strategy, rounds=2, **kw):
    kw.setdefault("snr_db", 40.0)
    kw.setdefault("eval_samples", 256)
    kw.setdefault("seed", 0)
    return FLConfig(strategy=strategy, rounds=rounds, **kw)


def _run(wl, cfg, **kw):
    init, apply, loss, topo, xs, ys, xte, yte = wl
    return run_rounds(init, apply, loss, topo, xs, ys, xte, yte, cfg, **kw)


def _mc(wl, cfg, **kw):
    init, apply, loss, topo, xs, ys, xte, yte = wl
    return run_monte_carlo(init, apply, loss, topo, xs, ys, xte, yte, cfg,
                           **kw)


def _assert_tree_bitwise(a, b, where=""):
    """Recursive bitwise equality of materialized payload trees (dicts/
    lists of np arrays) — NaN-tolerant via bit-pattern comparison."""
    if isinstance(a, dict) or isinstance(b, dict):
        assert isinstance(a, dict) and isinstance(b, dict), \
            f"{where}: {type(a)} vs {type(b)}"
        assert sorted(a) == sorted(b), f"{where}: keys {sorted(a)} vs " \
                                       f"{sorted(b)}"
        for k in a:
            _assert_tree_bitwise(a[k], b[k], f"{where}.{k}")
        return
    if isinstance(a, (list, tuple)):
        assert len(a) == len(b), f"{where}: len {len(a)} vs {len(b)}"
        for i, (x, y) in enumerate(zip(a, b)):
            _assert_tree_bitwise(x, y, f"{where}[{i}]")
        return
    a, b = np.asarray(a), np.asarray(b)
    assert a.shape == b.shape, f"{where}: shape {a.shape} vs {b.shape}"
    assert np.array_equal(np.atleast_1d(a).view(np.uint8),
                          np.atleast_1d(b).view(np.uint8)), \
        f"{where}: bits differ"


def _assert_stream_is_posthoc(records, h, rounds, seed=0, snr_db=40.0):
    """Every streamed record == the post-hoc history slice, bitwise."""
    assert len(records) == rounds
    tele_tree = _np_tree(h["telemetry"])
    loss = np.asarray(h["train_loss"])
    acc = np.asarray(h["test_acc"])
    for rec in records:
        t = rec["round"] - 1
        assert rec["seed"] == seed and rec["snr_db"] == snr_db
        _assert_tree_bitwise(np.asarray(rec["train_loss"]), loss[t],
                             "train_loss")
        _assert_tree_bitwise(np.asarray(rec["test_acc"]), acc[t],
                             "test_acc")
        _assert_tree_bitwise(rec["telemetry"], _tree_index(tele_tree, t),
                             f"telemetry[t={t}]")


# ---------------------------------------------------------------------------
# Stream-off: the tap is a static no-op.
# ---------------------------------------------------------------------------

def test_stream_off_jaxpr_byte_identical(wl):
    """``stream=None`` leaves the telemetry build's jaxpr byte-identical
    to a build that never saw the stream kwarg (normalized for heap
    addresses) — and free of callback primitives entirely."""
    from repro.sim.engine import _build, make_trajectory_fn
    from repro.sim.scenarios import Scenario

    init, apply, loss, topo, xs, ys, xte, yte = wl
    cfg = _cfg("cwfl")

    def jaxpr_of(**kw):
        prepare, make_body = _build(init, apply, loss, topo, xs, ys, xte,
                                    yte, cfg, Scenario(), TCFG,
                                    telemetry=True, **kw)
        traj = make_trajectory_fn(prepare, make_body)
        txt = str(jax.make_jaxpr(traj)(0, 40.0))
        return re.sub(r"0x[0-9a-f]+", "0xADDR", txt)

    base = jaxpr_of()                    # pre-stream call signature
    off = jaxpr_of(stream=None)
    assert off == base
    assert "callback" not in off
    on = jaxpr_of(stream=RoundStream([MemorySink()]))
    assert on != off and "callback" in on


# ---------------------------------------------------------------------------
# Stream-on: bit-neutral, and the stream IS the post-hoc telemetry.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", STRATEGIES)
def test_stream_matches_posthoc_bitwise(wl, strategy):
    cfg = _cfg(strategy)
    ref = _run(wl, cfg, telemetry=True)
    sink = MemorySink()
    stream = RoundStream([sink])
    h = _run(wl, cfg, telemetry=True, stream=stream)
    for key in ("train_loss", "test_acc"):
        assert np.array_equal(np.asarray(h[key]), np.asarray(ref[key])), \
            f"{strategy}: streamed run perturbed {key}"
    _assert_stream_is_posthoc(stream.records(), h, cfg.rounds)
    assert sink.of_type("stream") == stream.records()
    assert not stream.errors


def test_stream_requires_telemetry(wl):
    with pytest.raises(ValueError):
        _run(wl, _cfg("cwfl"), stream=RoundStream([MemorySink()]))


def test_mc_vmap_stream_bitwise(wl):
    """Monte-Carlo (vmap) streaming: post-scan trajectory tap — metrics
    bitwise vs the untapped sweep, one record per (seed, round)."""
    cfg = _cfg("cwfl")
    ref = _mc(wl, cfg, seeds=2, telemetry=True)
    stream = RoundStream([MemorySink()])
    h = _mc(wl, cfg, seeds=2, telemetry=True, stream=stream)
    for key in ("train_loss", "test_acc"):
        assert np.array_equal(np.asarray(h[key]), np.asarray(ref[key]))
    assert len(stream.records()) == 2 * cfg.rounds
    tele_tree = _np_tree(h["telemetry"])
    for s in range(2):
        recs = stream.for_trajectory(seed=s, snr_db=40.0)
        assert [r["round"] for r in recs] == list(range(1, cfg.rounds + 1))
        for rec in recs:
            t = rec["round"] - 1
            _assert_tree_bitwise(
                np.asarray(rec["train_loss"]),
                np.asarray(h["train_loss"])[s, t], "train_loss")
            _assert_tree_bitwise(
                rec["telemetry"],
                _tree_index(_tree_index(tele_tree, s), t),
                f"telemetry[s={s},t={t}]")


@multi_device
def test_mc_sharded_stream_rank0(wl):
    """mc-sharded streaming: only rank 0's trajectory chunk is emitted
    (the host-side scope drops the rest), records bitwise vs history."""
    n_dev = len(jax.devices())
    seeds = n_dev  # one trajectory per device -> rank 0 owns seed 0
    cfg = _cfg("cwfl")
    stream = RoundStream([MemorySink()])
    h = _mc(wl, cfg, seeds=seeds, shard="mc", telemetry=True,
            stream=stream)
    recs = stream.records()
    assert {r["seed"] for r in recs} == {0}
    assert len(recs) == cfg.rounds
    # the MC tap fires once per trajectory (rounds expand host-side), so
    # each off-scope trajectory counts one drop
    assert stream.dropped == seeds - 1
    tele_tree = _np_tree(h["telemetry"])
    for rec in recs:
        t = rec["round"] - 1
        _assert_tree_bitwise(
            np.asarray(rec["train_loss"]),
            np.asarray(h["train_loss"])[0, t], "train_loss")
        _assert_tree_bitwise(
            rec["telemetry"], _tree_index(_tree_index(tele_tree, 0), t),
            f"telemetry[t={t}]")


@multi_device
def test_client_sharded_stream_bitwise(wl):
    """client-sharded streaming (unordered tap, rank-0 host filter):
    metrics bitwise vs the unsharded run, stream == post-hoc."""
    from repro.launch.mesh import make_client_mesh

    cfg = _cfg("cwfl")
    ref = _run(wl, cfg, telemetry=True)
    stream = RoundStream([MemorySink()])
    h = _run(wl, cfg, shard="clients", mesh=make_client_mesh(),
             telemetry=True, stream=stream)
    for key in ("train_loss", "test_acc"):
        assert np.array_equal(np.asarray(h[key]), np.asarray(ref[key]))
    _assert_stream_is_posthoc(stream.records(), h, cfg.rounds)


def test_resume_continues_stream(wl, tmp_path):
    """Crash at round 2 of 4, resume: the resumed segments emit ABSOLUTE
    rounds 3..4 and the cumulative ledger continues from the checkpoint
    — together the two streams equal an uninterrupted run's."""
    cfg = _cfg("cwfl", rounds=4)
    ref_stream = RoundStream([MemorySink()])
    ref = _run(wl, cfg, telemetry=True, stream=ref_stream)

    ck = str(tmp_path / "ck")
    s1 = RoundStream([MemorySink()])
    _run(wl, cfg, telemetry=True, stream=s1, checkpoint_dir=ck,
         checkpoint_every=1, stop_after=2)
    assert [r["round"] for r in s1.records()] == [1, 2]
    s2 = RoundStream([MemorySink()])
    h = _run(wl, cfg, telemetry=True, stream=s2, checkpoint_dir=ck,
             checkpoint_every=1, resume=True)
    assert [r["round"] for r in s2.records()] == [3, 4]
    for key in ("train_loss", "test_acc"):
        assert np.array_equal(np.asarray(h[key]), np.asarray(ref[key]))
    merged = s1.records() + s2.records()
    for rec, ref_rec in zip(merged, ref_stream.records()):
        _assert_tree_bitwise(rec["telemetry"], ref_rec["telemetry"],
                             f"round {rec['round']}")


def test_abort_on_alert_checkpoint_then_stop(wl, tmp_path):
    """An escalating alert stops the run at the next checkpoint boundary;
    the aborted run resumes to completion."""
    cfg = _cfg("cwfl", rounds=4)
    ck = str(tmp_path / "ck")
    mon = Monitor([ConsensusDriftRule(max_drift=1e-9)],
                  abort_on_alert=True)
    stream = RoundStream([MemorySink()], monitor=mon)
    h = _run(wl, cfg, telemetry=True, stream=stream, checkpoint_dir=ck,
             checkpoint_every=1)
    assert stream.should_abort
    assert np.asarray(h["train_loss"]).shape[0] == 1     # stopped early
    h2 = _run(wl, cfg, telemetry=True,
              stream=RoundStream([MemorySink()]), checkpoint_dir=ck,
              checkpoint_every=1, resume=True)
    assert np.asarray(h2["train_loss"]).shape[0] == cfg.rounds


def test_abort_without_checkpoint_raises(wl):
    mon = Monitor(default_rules(), abort_on_alert=True)
    with pytest.raises(ValueError):
        _run(wl, _cfg("cwfl"), telemetry=True,
             stream=RoundStream([MemorySink()], monitor=mon))


# ---------------------------------------------------------------------------
# Monitor rules: fire on synthetic violations, silent on healthy runs.
# ---------------------------------------------------------------------------

def _rec(round=1, seed=0, snr_db=40.0, train_loss=2.0, drift=(0.5, 0.6),
         extras=None, **tele):
    telemetry = {"cluster_loss": [2.0, 2.1], "participants": 8.0,
                 "consensus_drift": list(drift), "channel_uses": 9.0,
                 "cum_channel_uses": 9.0 * round, "cum_symbols": 100.0,
                 "reclustered": 0.0, "extras": extras or {}}
    telemetry.update(tele)
    return {"type": "stream", "round": round, "seed": seed,
            "snr_db": snr_db, "train_loss": train_loss, "test_acc": 0.5,
            "telemetry": telemetry}


def test_nonfinite_loss_rule():
    mon = Monitor([NonFiniteLossRule()])
    assert not mon.observe(_rec())
    alerts = mon.observe(_rec(round=2, train_loss=float("nan")))
    assert [a.rule for a in alerts] == ["non_finite_loss"]
    assert alerts[0].round == 2
    rec = alerts[0].to_record()
    assert rec["type"] == "alert" and rec["trajectory"]["seed"] == 0


def test_consensus_drift_rule_blowup():
    mon = Monitor([ConsensusDriftRule(max_drift=100.0, blowup=50.0)])
    assert not mon.observe(_rec(round=1, drift=(0.5,)))
    # 60x the round-1 baseline trips the blowup arm under the ceiling.
    assert mon.observe(_rec(round=2, drift=(30.0,)))
    # Separate trajectory, separate baseline: silent.
    assert not mon.observe(_rec(round=1, seed=7, drift=(30.0,)))


def test_quarantine_rate_rule():
    mon = Monitor([QuarantineRateRule(max_rate=0.5)])
    assert not mon.observe(_rec())                       # no fault plane
    extras = {"fault_quarantined": 6.0,
              "fault_alive": [1.0] * 8}
    assert mon.observe(_rec(extras=extras))


def test_power_budget_rule():
    mon = Monitor([PowerBudgetRule(tol=1.05)])
    assert not mon.observe(_rec(extras={"power_budget_frac": 1.0}))
    alerts = mon.observe(_rec(round=2,
                              extras={"power_budget_frac": 1.2}))
    assert [a.rule for a in alerts] == ["power_budget"]


def test_convergence_stall_rule():
    stall = ConvergenceStallRule(min_rounds=6, rel_tol=0.5)
    mon = Monitor([stall])
    # A clean c/T envelope: silent through 10 rounds.
    for t in range(1, 11):
        assert not mon.observe(_rec(round=t, train_loss=1.0 + 3.0 / t))
    # A rising trajectory (c < 0) fires once enough rounds accumulate.
    mon2 = Monitor([ConvergenceStallRule(min_rounds=6, rel_tol=0.5)])
    fired = []
    for t in range(1, 11):
        fired += mon2.observe(_rec(round=t, train_loss=1.0 + 0.3 * t))
    assert any(a.rule == "convergence_stall" for a in fired)


def test_broken_rule_is_contained():
    class Bomb(ConsensusDriftRule):
        name = "bomb"

        def observe(self, rec):
            raise RuntimeError("boom")

    mon = Monitor([Bomb()])
    alerts = mon.observe(_rec())
    assert [a.rule for a in alerts] == ["bomb!error"]


def test_abort_on_named_rules_only():
    mon = Monitor([NonFiniteLossRule(), PowerBudgetRule()],
                  abort_on_alert=["non_finite_loss"])
    mon.observe(_rec(extras={"power_budget_frac": 2.0}))
    assert not mon.should_abort
    mon.observe(_rec(round=2, train_loss=float("inf")))
    assert mon.should_abort


def test_default_rules_silent_on_healthy_stream(wl):
    """The CI invariant: zero alerts on a healthy paper-static run."""
    mon = Monitor(default_rules())
    stream = RoundStream([MemorySink()], monitor=mon)
    _run(wl, _cfg("cwfl", rounds=3), telemetry=True, stream=stream)
    assert mon.summary()["alerts"] == 0


# ---------------------------------------------------------------------------
# Sinks + the terminal watcher.
# ---------------------------------------------------------------------------

def test_jsonl_sink_appends_and_prom_textfile(tmp_path):
    path = tmp_path / "s.jsonl"
    sink = JsonlStreamSink(str(path))
    sink.write({"type": "manifest", "x": 1})
    sink.write(_rec())
    sink.close()
    sink2 = JsonlStreamSink(str(path), append=True)    # resume mode
    sink2.write(_rec(round=2))
    sink2.close()
    lines = [json.loads(l) for l in path.read_text().splitlines()]
    assert [l.get("round") for l in lines] == [None, 1, 2]

    prom = tmp_path / "s.prom"
    ps = PrometheusSink(str(prom))
    ps.write(_rec(round=3))
    ps.write({"type": "alert", "rule": "power_budget",
              "trajectory": {"seed": 0, "snr_db": 40.0}})
    ps.close()
    text = prom.read_text()
    assert 'repro_round{seed="0",snr_db="40"} 3' in text
    assert "repro_alerts_total" in text


def test_watch_run_renders_and_gates(tmp_path):
    path = tmp_path / "s.jsonl"
    sink = JsonlStreamSink(str(path))
    for t in range(1, 4):
        sink.write(_rec(round=t, train_loss=3.0 - 0.5 * t))
    sink.close()
    script = os.path.join(os.path.dirname(__file__), "..", "examples",
                          "watch_run.py")
    r = subprocess.run([sys.executable, script, str(path)],
                       capture_output=True, text=True)
    assert r.returncode == 0, r.stderr
    assert "round 3" in r.stdout and "cum_uses" in r.stdout

    sink = JsonlStreamSink(str(path), append=True)
    sink.write({"type": "alert", "rule": "nonfinite_loss", "round": 4,
                "trajectory": {"seed": 0, "snr_db": 40.0},
                "message": "loss is nan"})
    sink.close()
    r = subprocess.run([sys.executable, script, str(path),
                        "--fail-on-alert"], capture_output=True, text=True)
    assert r.returncode == 2
    assert "nonfinite_loss" in r.stdout
