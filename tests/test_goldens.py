"""Golden-trajectory regression: replay the engine against committed bits.

Three PRs of refactors (fused kernel, scan engine, sharded execution)
each proved bit-identity against the code they replaced — but only by
re-running the pre-refactor code in the same process.  This fixture
commits the ``paper-static`` T=4/K=8 histories for all four strategies
as raw float32 bit patterns (``tests/goldens/paper_static_T4_K8.json``),
so every future refactor gets a parity check against TODAY's bits
without a pre-refactor checkout.

Regenerate intentionally with ``PYTHONPATH=src python
tests/goldens/generate.py`` — a diff of the ``*_repr`` fields documents
the drift.  The exact bits are pinned to the config that generated them
(CPU backend, 8 fake devices — CI's tier-1 layout): XLA CPU tiles
reductions by the device/thread config, which legally re-associates a
mean by 1 ulp.  Under any other config the test enforces a 2-ulp bound
instead — still tight enough that any real regression (wrong key
schedule, changed math) fails loudly.
"""
import json
import os

import jax
import numpy as np
import pytest

from goldens.generate import GOLDEN_DIR, STRATEGIES, run_strategy

GOLDEN_PATH = os.path.join(GOLDEN_DIR, "paper_static_T4_K8.json")


def _from_bits(hexes):
    return np.asarray([int(h, 16) for h in hexes],
                      np.uint32).view(np.float32)


def _ulp_dist(a: np.ndarray, b: np.ndarray) -> int:
    ia = a.astype(np.float32).view(np.int32).astype(np.int64)
    ib = b.astype(np.float32).view(np.int32).astype(np.int64)
    return int(np.max(np.abs(ia - ib)))


@pytest.fixture(scope="module")
def golden():
    with open(GOLDEN_PATH) as f:
        return json.load(f)


def _pinned_config(golden) -> bool:
    p = golden["protocol"]
    return (jax.default_backend() == p["backend"]
            and len(jax.devices()) == p["devices"]
            # an XLA upgrade may legitimately re-fuse by a ulp — route
            # version drift to the 2-ulp bound, not the bitwise pin
            and jax.__version__ == p["jax"])


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_golden_trajectory_replay(golden, strategy):
    loss, acc = run_strategy(strategy)
    g = golden["strategies"][strategy]
    g_loss = _from_bits(g["train_loss_bits"])
    g_acc = _from_bits(g["test_acc_bits"])
    max_ulp = 0 if _pinned_config(golden) else 2
    for name, got, want in (("train_loss", loss, g_loss),
                            ("test_acc", acc, g_acc)):
        ulp = _ulp_dist(got, want)
        assert ulp <= max_ulp, (
            f"{strategy} {name} drifted from the golden by {ulp} ulp "
            f"(bound {max_ulp}): {got} vs {want}")


def test_golden_fixture_is_self_consistent(golden):
    """The human-readable repr fields decode to the same floats as the
    bit patterns (guards against hand-editing one but not the other)."""
    for s, g in golden["strategies"].items():
        np.testing.assert_array_equal(
            _from_bits(g["train_loss_bits"]),
            np.asarray(g["train_loss_repr"], np.float32), err_msg=s)
        np.testing.assert_array_equal(
            _from_bits(g["test_acc_bits"]),
            np.asarray(g["test_acc_repr"], np.float32), err_msg=s)
