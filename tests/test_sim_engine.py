"""Scan-engine equivalence + scenario behaviour (repro.sim.engine).

The heart of the subsystem's correctness story: the scanned trajectory
under the ``paper-static`` scenario must reproduce the legacy per-round
loop (and hence the pre-refactor `run_federated`) BIT-FOR-BIT, and the
participation-mask machinery must be exactly inert at an all-ones mask.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import TopologyConfig, make_topology
from repro.data import SyntheticImageConfig, make_synthetic_images, partition_iid
from repro.models import make_mnist_mlp, nll_loss
from repro.sim import (Scenario, ScheduleConfig, get_scenario,
                       run_monte_carlo, run_rounds)
from repro.training import FLConfig, run_federated

K = 8
TCFG = TopologyConfig(num_clients=K, num_hotspots=3)


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    dcfg = SyntheticImageConfig.mnist_like(num_train=960, num_test=256)
    (xtr, ytr), (xte, yte) = make_synthetic_images(key, dcfg)
    topo = make_topology(jax.random.PRNGKey(7), TCFG)
    xs, ys = partition_iid(jax.random.PRNGKey(1), xtr, ytr, K)
    init, apply = make_mnist_mlp(hidden=(32,))
    loss = lambda p, x, y: nll_loss(apply(p, x), y)
    return init, apply, loss, topo, xs, ys, xte, yte


def _hist_equal(h1, h2):
    return (bool(jnp.array_equal(h1["train_loss"], h2["train_loss"]))
            and bool(jnp.array_equal(h1["test_acc"], h2["test_acc"])))


# ---------------------------------------------------------------------------
# Satellite: static-scenario scan == legacy loop, bit-for-bit.
# ---------------------------------------------------------------------------

def test_scan_equals_loop_bitwise_cwfl(setup):
    """Tiny MLP, odd round count (exercises the unroll=2 remainder): the
    single-jit scanned trajectory reproduces the per-round-jit loop — the
    pre-refactor `run_federated` structure — exactly."""
    init, apply, loss, topo, xs, ys, xte, yte = setup
    cfg = FLConfig(strategy="cwfl", rounds=5, snr_db=40.0,
                   eval_samples=256, seed=3)
    h_scan = run_rounds(init, apply, loss, topo, xs, ys, xte, yte, cfg,
                        mode="scan")
    h_loop = run_rounds(init, apply, loss, topo, xs, ys, xte, yte, cfg,
                        mode="loop")
    assert _hist_equal(h_scan, h_loop)
    for a, b in zip(jax.tree.leaves(h_scan["final_params"]),
                    jax.tree.leaves(h_loop["final_params"])):
        assert bool(jnp.array_equal(a, b))


@pytest.mark.parametrize("strategy", ["cotaf", "fedavg", "decentralized"])
@pytest.mark.slow
def test_scan_equals_loop_bitwise_baselines(setup, strategy):
    init, apply, loss, topo, xs, ys, xte, yte = setup
    cfg = FLConfig(strategy=strategy, rounds=3, snr_db=40.0,
                   eval_samples=256, seed=3)
    h_scan = run_rounds(init, apply, loss, topo, xs, ys, xte, yte, cfg,
                        mode="scan")
    h_loop = run_rounds(init, apply, loss, topo, xs, ys, xte, yte, cfg,
                        mode="loop")
    assert _hist_equal(h_scan, h_loop)


def test_run_federated_wraps_engine_exactly(setup):
    """The compatibility wrapper's float lists match the engine arrays
    (and the progress-callback loop path matches the scan path)."""
    init, apply, loss, topo, xs, ys, xte, yte = setup
    cfg = FLConfig(strategy="cwfl", rounds=4, snr_db=40.0,
                   eval_samples=256, seed=1)
    h_eng = run_rounds(init, apply, loss, topo, xs, ys, xte, yte, cfg)
    seen = []
    h_wrap = run_federated(init, apply, loss, topo, xs, ys, xte, yte, cfg,
                           progress=lambda r, l, a: seen.append((r, l, a)))
    assert h_wrap["train_loss"] == [float(x) for x in h_eng["train_loss"]]
    assert h_wrap["test_acc"] == [float(x) for x in h_eng["test_acc"]]
    assert h_wrap["round"] == list(range(1, 5))
    assert len(seen) == 4 and seen[0][0] == 1
    assert h_wrap["avg_acc"] == pytest.approx(float(h_eng["avg_acc"]))


# ---------------------------------------------------------------------------
# Satellite: all-ones participation mask == unmasked path.
# ---------------------------------------------------------------------------

def test_engine_all_ones_mask_path_matches_static(setup):
    """A schedule with a huge energy budget is non-trivial (the mask code
    path runs every round) but produces all-ones masks — the trajectory
    must match the static path exactly."""
    init, apply, loss, topo, xs, ys, xte, yte = setup
    cfg = FLConfig(strategy="cwfl", rounds=3, snr_db=40.0,
                   eval_samples=256, seed=2)
    sc = Scenario(name="all-ones",
                  schedule=ScheduleConfig(energy_budget=1e9))
    h_mask = run_rounds(init, apply, loss, topo, xs, ys, xte, yte, cfg,
                        scenario=sc, topo_cfg=TCFG)
    h_ref = run_rounds(init, apply, loss, topo, xs, ys, xte, yte, cfg)
    np.testing.assert_allclose(np.asarray(h_mask["train_loss"]),
                               np.asarray(h_ref["train_loss"]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(h_mask["test_acc"]),
                               np.asarray(h_ref["test_acc"]), atol=1e-6)


# ---------------------------------------------------------------------------
# Monte-Carlo: one jit over seeds × SNR grid.
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_monte_carlo_snr_sweep_single_jit(setup):
    init, apply, loss, topo, xs, ys, xte, yte = setup
    cfg = FLConfig(strategy="cwfl", rounds=2, eval_samples=256, seed=0)
    sc = get_scenario("snr-sweep")
    h = run_monte_carlo(init, apply, loss, topo, xs, ys, xte, yte, cfg,
                        scenario=sc, seeds=2)
    G = len(sc.snr_grid)
    assert h["train_loss"].shape == (2, G, 2)
    assert h["test_acc"].shape == (2, G, 2)
    assert h["final_acc"].shape == (2, G)
    assert bool(jnp.isfinite(h["train_loss"]).all())
    # distinct seeds produce distinct trajectories
    assert not bool(jnp.array_equal(h["train_loss"][0], h["train_loss"][1]))


@pytest.mark.slow
def test_monte_carlo_seed_axis_matches_single_run(setup):
    """Each vmapped Monte-Carlo element reproduces the standalone scanned
    trajectory for that seed (batching must not change the math beyond
    reassociation-level noise)."""
    init, apply, loss, topo, xs, ys, xte, yte = setup
    cfg = FLConfig(strategy="cwfl", rounds=2, snr_db=40.0,
                   eval_samples=256, seed=11)
    h_mc = run_monte_carlo(init, apply, loss, topo, xs, ys, xte, yte, cfg,
                           seeds=2)
    assert h_mc["train_loss"].shape == (2, 2)
    h1 = run_rounds(init, apply, loss, topo, xs, ys, xte, yte, cfg)
    np.testing.assert_allclose(np.asarray(h_mc["train_loss"][0]),
                               np.asarray(h1["train_loss"]), rtol=2e-5)
    np.testing.assert_allclose(np.asarray(h_mc["test_acc"][0]),
                               np.asarray(h1["test_acc"]), atol=1e-2)


# ---------------------------------------------------------------------------
# Dynamic scenarios run and stay sane.
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("name", ["mobile-fading", "cluster-churn",
                                  "straggler-heavy"])
def test_dynamic_scenarios_run(setup, name):
    init, apply, loss, topo, xs, ys, xte, yte = setup
    cfg = FLConfig(strategy="cwfl", rounds=2, snr_db=40.0,
                   eval_samples=256, seed=0)
    h = run_rounds(init, apply, loss, topo, xs, ys, xte, yte, cfg,
                   scenario=get_scenario(name), topo_cfg=TCFG)
    loss_arr = np.asarray(h["train_loss"])
    assert loss_arr.shape == (2,) and np.isfinite(loss_arr).all()
    # the dynamic world actually differs from the static one — compare the
    # final consensus params (train_loss lags masking by a round and the
    # argmax accuracy is too coarse to register small consensus shifts)
    h_ref = run_rounds(init, apply, loss, topo, xs, ys, xte, yte, cfg)
    assert any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(h["final_params"]),
                        jax.tree.leaves(h_ref["final_params"])))


def test_all_masked_round_skips_sync(setup):
    """Every client straggling every round ⇒ no OTA sync ever happens:
    the consensus (and hence the reported accuracy) stays frozen at the
    initial parameters while clients keep training locally."""
    init, apply, loss, topo, xs, ys, xte, yte = setup
    cfg = FLConfig(strategy="cwfl", rounds=3, snr_db=40.0,
                   eval_samples=256, seed=2)
    sc = Scenario(name="blackout",
                  schedule=ScheduleConfig(num_stragglers=K,
                                          straggler_period=1))
    h = run_rounds(init, apply, loss, topo, xs, ys, xte, yte, cfg,
                   scenario=sc, topo_cfg=TCFG)
    acc = np.asarray(h["test_acc"])
    assert np.isfinite(np.asarray(h["train_loss"])).all()
    assert (acc == acc[0]).all()          # consensus never updated
    # local training still progressed (loss changes across rounds)
    loss_arr = np.asarray(h["train_loss"])
    assert not (loss_arr == loss_arr[0]).all()


def test_csi_only_scenario_needs_no_topo_cfg(setup):
    """Imperfect CSI alone perturbs only the water-filling gains — no
    geometry evolution, so no TopologyConfig is required and the result
    differs from perfect-CSI only through the power allocation."""
    from repro.sim import ChannelProcessConfig
    init, apply, loss, topo, xs, ys, xte, yte = setup
    cfg = FLConfig(strategy="cwfl", rounds=2, snr_db=40.0,
                   eval_samples=256, seed=4)
    sc = Scenario(name="csi-only",
                  channel=ChannelProcessConfig(csi_error_std=0.5))
    assert not sc.channel.evolves_geometry and sc.channel.is_dynamic
    h = run_rounds(init, apply, loss, topo, xs, ys, xte, yte, cfg,
                   scenario=sc)                   # no topo_cfg
    assert np.isfinite(np.asarray(h["train_loss"])).all()
    h_ref = run_rounds(init, apply, loss, topo, xs, ys, xte, yte, cfg)
    assert any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(h["final_params"]),
                        jax.tree.leaves(h_ref["final_params"])))


def test_dynamic_channel_requires_topo_cfg(setup):
    init, apply, loss, topo, xs, ys, xte, yte = setup
    cfg = FLConfig(strategy="cwfl", rounds=1, snr_db=40.0, eval_samples=64)
    with pytest.raises(ValueError, match="TopologyConfig"):
        run_rounds(init, apply, loss, topo, xs, ys, xte, yte, cfg,
                   scenario=get_scenario("mobile-fading"))


def test_unknown_strategy_raises(setup):
    init, apply, loss, topo, xs, ys, xte, yte = setup
    with pytest.raises(KeyError, match="unknown strategy"):
        run_rounds(init, apply, loss, topo, xs, ys, xte, yte,
                   FLConfig(strategy="nope", rounds=1))
