"""Observability subsystem (repro.obs, DESIGN.md §Obs).

The load-bearing contract: telemetry is a STATIC opt-in — with the flag
off the engine's traced computation is byte-identical to the pre-obs
build (the committed goldens replay bitwise, pinned by
``tests/test_goldens.py`` since telemetry-off IS the default path), and
with the flag on the ``train_loss``/``test_acc`` history is STILL
bit-for-bit unchanged: every telemetry quantity reads already-
materialized round intermediates plus one fresh full-shard loss eval
(never the fusion-sensitive minibatch loss buffer — see
`repro.sim.engine`).  Plus: the channel-use ledger as the one source of
truth for the paper's §IV cost claim, manifest determinism, the JSONL
sink round-trip, and the report renderer.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from goldens.generate import GOLDEN_DIR, STRATEGIES, workload
from repro.core import TopologyConfig, cwfl
from repro.obs import (PhaseTimers, RoundTelemetry, build_manifest,
                       config_hash, per_client_dim, per_round_table,
                       read_run, symbols_per_round, to_jsonable,
                       uses_per_round, write_history)
from repro.sim import get_scenario, run_monte_carlo, run_rounds
from repro.training import FLConfig

K = 8
TCFG = TopologyConfig(num_clients=K, num_hotspots=3)
GOLDEN_PATH = os.path.join(GOLDEN_DIR, "paper_static_T4_K8.json")

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >1 device (CI: XLA_FLAGS=--xla_force_host_platform_"
           "device_count=8)")


@pytest.fixture(scope="module")
def wl():
    return workload()


def _cfg(strategy, rounds=2, **kw):
    kw.setdefault("snr_db", 40.0)
    kw.setdefault("eval_samples", 256)
    kw.setdefault("seed", 0)
    return FLConfig(strategy=strategy, rounds=rounds, **kw)


def _run(wl, cfg, **kw):
    init, apply, loss, topo, xs, ys, xte, yte = wl
    return run_rounds(init, apply, loss, topo, xs, ys, xte, yte, cfg, **kw)


def _ulp_dist(a, b) -> int:
    ia = np.asarray(a, np.float32).view(np.int32).astype(np.int64)
    ib = np.asarray(b, np.float32).view(np.int32).astype(np.int64)
    return int(np.max(np.abs(ia - ib)))


# ---------------------------------------------------------------------------
# The bit-neutrality contract: telemetry-on leaves the history unchanged.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", STRATEGIES)
def test_telemetry_on_replays_golden_bits(strategy):
    """Telemetry-ON at the exact golden protocol reproduces the committed
    telemetry-off bits — recording observations must not perturb the
    trajectory (same bound as tests/test_goldens.py: bitwise on the
    pinned CI config, ≤2 ulp elsewhere)."""
    from goldens.generate import run_strategy  # telemetry-off reference

    with open(GOLDEN_PATH) as f:
        golden = json.load(f)
    p = golden["protocol"]
    pinned = (jax.default_backend() == p["backend"]
              and len(jax.devices()) == p["devices"]
              and jax.__version__ == p["jax"])
    max_ulp = 0 if pinned else 2

    init, apply, loss, topo, xs, ys, xte, yte = workload()
    cfg = FLConfig(strategy=strategy, rounds=4, snr_db=40.0,
                   eval_samples=256, seed=0)
    h = run_rounds(init, apply, loss, topo, xs, ys, xte, yte, cfg,
                   telemetry=True)
    g = golden["strategies"][strategy]
    want_loss = np.asarray(
        [int(x, 16) for x in g["train_loss_bits"]], np.uint32
    ).view(np.float32)
    want_acc = np.asarray(
        [int(x, 16) for x in g["test_acc_bits"]], np.uint32
    ).view(np.float32)
    for name, got, want in (("train_loss", h["train_loss"], want_loss),
                            ("test_acc", h["test_acc"], want_acc)):
        ulp = _ulp_dist(got, want)
        assert ulp <= max_ulp, (
            f"{strategy} telemetry-on {name} drifted {ulp} ulp from the "
            f"telemetry-off golden (bound {max_ulp})")
    assert "telemetry" in h


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_telemetry_pytree_shapes_and_finiteness(wl, strategy):
    """Every strategy's telemetry rides the scan: round-stacked leading
    axis, fixed shapes, finite values, monotone ledger."""
    T = 2
    h = _run(wl, _cfg(strategy, rounds=T), telemetry=True)
    tele = h["telemetry"]
    assert isinstance(tele, RoundTelemetry)
    for leaf in jax.tree.leaves(tele):
        assert leaf.shape[0] == T
        assert bool(jnp.isfinite(leaf).all())
    C = tele.cluster_loss.shape[1]
    assert tele.consensus_drift.shape == (T, C)
    assert tele.participants.shape == (T,)
    np.testing.assert_array_equal(np.asarray(tele.participants),
                                  np.full(T, float(K)))
    # ledger: per-round uses match the strategy's arithmetic, cumulative
    # sums are exact (integer-valued float accumulation)
    uses = float(uses_per_round(strategy, K, 3))
    np.testing.assert_array_equal(np.asarray(tele.channel_uses),
                                  np.full(T, uses))
    np.testing.assert_array_equal(np.asarray(tele.cum_channel_uses),
                                  uses * np.arange(1, T + 1))
    init, *_ = wl
    d = per_client_dim(jax.tree.map(
        lambda x: x[None], init(jax.random.PRNGKey(0))))
    np.testing.assert_array_equal(np.asarray(tele.cum_symbols),
                                  uses * d * np.arange(1, T + 1))


def test_masked_scenario_telemetry(wl):
    """straggler-heavy: effective participation drops below K and the
    CWFL extras stay finite under masked rounds."""
    h = _run(wl, _cfg("cwfl", rounds=4), scenario=get_scenario(
        "straggler-heavy"), topo_cfg=TCFG, telemetry=True)
    tele = h["telemetry"]
    p = np.asarray(tele.participants)
    assert (p <= K).all() and p.min() < K
    for leaf in jax.tree.leaves(tele):
        assert bool(jnp.isfinite(leaf).all())
    # telemetry-on leaves the masked trajectory unchanged too
    h_off = _run(wl, _cfg("cwfl", rounds=4), scenario=get_scenario(
        "straggler-heavy"), topo_cfg=TCFG)
    assert bool(jnp.array_equal(h["train_loss"], h_off["train_loss"]))
    assert bool(jnp.array_equal(h["test_acc"], h_off["test_acc"]))


def test_recluster_events_recorded(wl):
    """cluster-churn (recluster_every=5): the ``reclustered`` flag marks
    exactly the rounds where the lax.cond gate fired (t % 5 == 0)."""
    sc = get_scenario("cluster-churn")
    T = 7
    h = _run(wl, _cfg("cwfl", rounds=T), scenario=sc, topo_cfg=TCFG,
             telemetry=True)
    want = (np.arange(T) % sc.recluster_every == 0).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(h["telemetry"].reclustered),
                                  want)


def test_monte_carlo_telemetry_batches(wl):
    """MC sweeps batch the telemetry pytree over the seed axis."""
    init, apply, loss, topo, xs, ys, xte, yte = wl
    S, T = 2, 2
    h = run_monte_carlo(init, apply, loss, topo, xs, ys, xte, yte,
                        _cfg("cwfl", rounds=T), seeds=S, telemetry=True)
    tele = h["telemetry"]
    assert tele.cluster_loss.shape == (S, T, 3)
    assert tele.participants.shape == (S, T)
    np.testing.assert_array_equal(
        np.asarray(tele.cum_channel_uses)[:, -1],
        np.full(S, float(uses_per_round("cwfl", K, 3)) * T))


def test_loop_mode_telemetry_matches_scan(wl):
    """mode='loop' stacks per-round telemetry into the same pytree the
    scan emits (same shapes; histories bit-identical as ever)."""
    h_scan = _run(wl, _cfg("cwfl"), telemetry=True)
    h_loop = _run(wl, _cfg("cwfl"), telemetry=True, mode="loop")
    assert bool(jnp.array_equal(h_scan["train_loss"], h_loop["train_loss"]))
    assert (jax.tree.structure(h_scan["telemetry"])
            == jax.tree.structure(h_loop["telemetry"]))
    for a, b in zip(jax.tree.leaves(h_scan["telemetry"]),
                    jax.tree.leaves(h_loop["telemetry"])):
        assert a.shape == b.shape


# ---------------------------------------------------------------------------
# Channel-use ledger: ONE source of truth for the §IV cost claim.
# ---------------------------------------------------------------------------

def test_ledger_arithmetic():
    assert uses_per_round("cwfl", 12, 3) == 3 * 2 + 3          # C(C−1)+C
    assert uses_per_round("decentralized", 50) == 50 * 49       # K(K−1)
    assert uses_per_round("cotaf", 50) == 1
    assert uses_per_round("fedavg", 50) == 0
    # masked decentralized: P(P−1) with the round's effective P
    assert uses_per_round("decentralized", 50, participants=10.0) == 90.0
    tab = per_round_table(50, 3)
    assert tab == {"cwfl": 9, "decentralized": 2450, "server_ota": 1}
    assert symbols_per_round("cwfl", dim=100, num_clients=50,
                             num_clusters=3) == 900


def test_core_channel_uses_delegates_to_ledger():
    """`repro.core.cwfl.channel_uses_per_round` resolves through the same
    ledger — the benchmark table and the in-scan ledger cannot disagree."""
    for K_, C_ in ((12, 3), (50, 4), (27, 8)):
        assert cwfl.channel_uses_per_round(K_, C_) == per_round_table(K_, C_)


# ---------------------------------------------------------------------------
# Manifests, sink, report.
# ---------------------------------------------------------------------------

def test_manifest_fields_and_hash_stability():
    cfg = _cfg("cwfl")
    man = build_manifest(cfg=cfg, scenario=get_scenario("paper-static"),
                         strategy="cwfl", extra={"note": "t"})
    for field in ("schema", "git", "jax_version", "backend", "device_count",
                  "config", "config_hash", "created_unix", "note"):
        assert field in man
    assert man["strategy"] == "cwfl" and man["scenario"] == "paper-static"
    assert man["config"]["rounds"] == cfg.rounds
    json.dumps(man)     # fully serializable
    # identical protocol ⇒ identical identity hash; any field change flips it
    man2 = build_manifest(cfg=cfg, scenario=get_scenario("paper-static"),
                          strategy="cwfl")
    assert man["config_hash"] == man2["config_hash"]
    man3 = build_manifest(cfg=_cfg("cwfl", rounds=3),
                          scenario=get_scenario("paper-static"),
                          strategy="cwfl")
    assert man["config_hash"] != man3["config_hash"]
    assert config_hash({"b": 1, "a": 2}) == config_hash({"a": 2, "b": 1})


def test_to_jsonable_handles_arrays_dataclasses_namedtuples():
    out = to_jsonable({"cfg": _cfg("cwfl"),
                       "arr": jnp.arange(3),
                       "scalar": jnp.float32(1.5),
                       "tele": RoundTelemetry(*([0.0] * 7), extras={})})
    json.dumps(out)
    assert out["arr"] == [0, 1, 2]
    assert out["scalar"] == 1.5
    assert out["cfg"]["strategy"] == "cwfl"


def test_sink_round_trip_and_report_render(wl, tmp_path):
    """write_history → read_run → examples/obs_report.py is the full
    observability pipeline on a real telemetry run."""
    h = _run(wl, _cfg("cwfl"), telemetry=True)
    man = build_manifest(cfg=_cfg("cwfl"), scenario="paper-static",
                         strategy="cwfl", extra={"clients": K})
    path = tmp_path / "run.jsonl"
    timers = PhaseTimers()
    with timers.phase("execute"):
        pass
    n = write_history(path, h, manifest=man, timings=timers.as_dict())
    assert n == 1 + 2 + 1        # manifest + T rounds + summary

    run = read_run(path)
    assert run["manifest"]["config_hash"] == man["config_hash"]
    assert len(run["rounds"]) == 2
    r1 = run["rounds"][0]
    assert r1["round"] == 1
    assert len(r1["telemetry"]["cluster_loss"]) == 3
    assert r1["telemetry"]["cum_channel_uses"] == 9.0
    assert run["summary"]["cum_channel_uses"] == 18.0
    assert "execute" in run["summary"]["timings"]

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ,
               PYTHONPATH=os.path.join(repo, "src"), JAX_PLATFORMS="cpu")
    out = subprocess.run(
        [sys.executable, os.path.join(repo, "examples", "obs_report.py"),
         str(path)], capture_output=True, text=True, env=env, timeout=120)
    assert out.returncode == 0, out.stderr
    md = out.stdout
    for section in ("# Observability report", "## Per-cluster convergence",
                    "## Communication cost", "## Phase timings"):
        assert section in md
    assert "cwfl saves" in md           # the §IV savings row


def test_monte_carlo_sink_tags_trajectories(wl, tmp_path):
    init, apply, loss, topo, xs, ys, xte, yte = wl
    h = run_monte_carlo(init, apply, loss, topo, xs, ys, xte, yte,
                        _cfg("cwfl"), seeds=2, telemetry=True)
    path = tmp_path / "mc.jsonl"
    write_history(path, h)
    run = read_run(path)
    assert len(run["rounds"]) == 4                  # 2 seeds × 2 rounds
    seeds = {r["seed"] for r in run["rounds"]}
    assert seeds == {0, 1}
    assert run["summary"]["trajectories"] == 2


def test_phase_timers_accumulate():
    t = PhaseTimers()
    with t.phase("a"):
        pass
    with t.phase("a"):
        pass
    with t.phase("b"):
        pass
    d = t.as_dict()
    assert set(d) == {"a", "b"} and all(v >= 0 for v in d.values())


# ---------------------------------------------------------------------------
# Device-parallel paths carry telemetry too.
# ---------------------------------------------------------------------------

@multi_device
def test_mc_sharded_telemetry_matches_vmap(wl):
    from repro.launch.mesh import make_mc_mesh
    init, apply, loss, topo, xs, ys, xte, yte = wl
    cfg = _cfg("cwfl")
    kw = dict(seeds=2, telemetry=True)
    h_v = run_monte_carlo(init, apply, loss, topo, xs, ys, xte, yte, cfg,
                          **kw)
    h_s = run_monte_carlo(init, apply, loss, topo, xs, ys, xte, yte, cfg,
                          shard="mc", mesh=make_mc_mesh(2), **kw)
    tv, ts = h_v["telemetry"], h_s["telemetry"]
    assert jax.tree.structure(tv) == jax.tree.structure(ts)
    for a, b in zip(jax.tree.leaves(tv), jax.tree.leaves(ts)):
        assert a.shape == b.shape
    # the ledger is exact integer arithmetic — sharding cannot move it
    np.testing.assert_array_equal(np.asarray(tv.cum_channel_uses),
                                  np.asarray(ts.cum_channel_uses))
    np.testing.assert_array_equal(np.asarray(tv.participants),
                                  np.asarray(ts.participants))


@multi_device
def test_client_sharded_telemetry(wl):
    from repro.launch.mesh import make_client_mesh
    h = _run(wl, _cfg("cwfl"), shard="clients",
             mesh=make_client_mesh(2), telemetry=True)
    tele = h["telemetry"]
    assert tele.cluster_loss.shape == (2, 3)
    for leaf in jax.tree.leaves(tele):
        assert bool(jnp.isfinite(leaf).all())
    np.testing.assert_array_equal(np.asarray(tele.cum_channel_uses),
                                  9.0 * np.arange(1, 3))
    # and the sharded history itself is unperturbed by recording
    h_off = _run(wl, _cfg("cwfl"), shard="clients", mesh=make_client_mesh(2))
    assert bool(jnp.array_equal(h["train_loss"], h_off["train_loss"]))
    assert bool(jnp.array_equal(h["test_acc"], h_off["test_acc"]))
