"""Channel processes + scheduling invariants (repro.sim, DESIGN.md §Sim)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cwfl
from repro.core.topology import TopologyConfig, make_topology
from repro.sim.processes import (ChannelProcessConfig, channel_view,
                                 csi_perturbation, init_channel, step_channel)
from repro.sim.scheduling import (ScheduleConfig, init_schedule,
                                  participation_mask)

K = 10
TCFG = TopologyConfig(num_clients=K, num_hotspots=2)


@pytest.fixture(scope="module")
def topo():
    return make_topology(jax.random.PRNGKey(0), TCFG)


# ---------------------------------------------------------------------------
# Channel processes.
# ---------------------------------------------------------------------------

def test_init_view_matches_topology(topo):
    """Round-0 realization reproduces the seed topology exactly."""
    st = init_channel(topo, TCFG, jax.random.PRNGKey(1))
    view = channel_view(st, TCFG)
    np.testing.assert_allclose(np.asarray(view.link_gain),
                               np.asarray(topo.link_gain), rtol=1e-6)
    assert bool(jnp.array_equal(view.adjacency, topo.adjacency))


def test_static_limit_is_exact(topo):
    """All knobs off ⇒ stepping never changes the channel (bit-for-bit)."""
    cfg = ChannelProcessConfig()          # rho=1, no shadow, no motion
    st = init_channel(topo, TCFG, jax.random.PRNGKey(1))
    v0 = channel_view(st, TCFG)
    for t in range(3):
        st = step_channel(st, cfg, TCFG, jax.random.PRNGKey(10 + t))
    v3 = channel_view(st, TCFG)
    assert bool(jnp.array_equal(v0.link_gain, v3.link_gain))
    assert bool(jnp.array_equal(v0.adjacency, v3.adjacency))


def test_fading_variance_is_stationary(topo):
    """Gauss-Markov update preserves E|h̃|² = 1 (unit Rayleigh power)."""
    cfg = ChannelProcessConfig(fading_rho=0.7)
    st = init_channel(topo, TCFG, jax.random.PRNGKey(1))
    for t in range(60):
        st = step_channel(st, cfg, TCFG, jax.random.PRNGKey(100 + t))
    off = ~np.eye(K, dtype=bool)
    power = float(np.mean(np.abs(np.asarray(st.h_tilde))[off] ** 2))
    assert 0.6 < power < 1.5


def test_fading_is_correlated_across_rounds(topo):
    """ρ close to 1 ⇒ successive realizations stay close; ρ = 0 ⇒ fresh."""
    st = init_channel(topo, TCFG, jax.random.PRNGKey(1))
    key = jax.random.PRNGKey(2)
    h0 = st.h_tilde
    near = step_channel(st, ChannelProcessConfig(fading_rho=0.99), TCFG, key)
    far = step_channel(st, ChannelProcessConfig(fading_rho=0.0), TCFG, key)
    d_near = float(jnp.mean(jnp.abs(near.h_tilde - h0) ** 2))
    d_far = float(jnp.mean(jnp.abs(far.h_tilde - h0) ** 2))
    assert d_near < 0.1 < d_far


def test_mobility_moves_and_rederives_graph(topo):
    cfg = ChannelProcessConfig(speed=5.0)
    st = init_channel(topo, TCFG, jax.random.PRNGKey(1))
    p0 = st.positions
    views = []
    for t in range(20):
        st = step_channel(st, cfg, TCFG, jax.random.PRNGKey(200 + t))
        views.append(channel_view(st, TCFG))
    assert float(jnp.max(jnp.abs(st.positions - p0))) > 1.0
    # waypoints keep clients near the deployment area
    assert float(jnp.max(st.positions)) < TCFG.area_size * 1.5
    # per-round graphs stay valid: symmetric, no self-links
    for v in views[-3:]:
        adj = np.asarray(v.adjacency)
        assert not adj.diagonal().any()
        assert (adj == adj.T).all()
        assert np.allclose(np.asarray(v.link_gain),
                           np.asarray(v.link_gain).T.conj())


def test_shadowing_changes_snr(topo):
    cfg = ChannelProcessConfig(shadowing_std_db=6.0, shadowing_rho=0.5)
    st = init_channel(topo, TCFG, jax.random.PRNGKey(1))
    st = step_channel(st, cfg, TCFG, jax.random.PRNGKey(3))
    v = channel_view(st, TCFG)
    assert not bool(jnp.array_equal(v.link_snr, topo.link_snr))
    sh = np.asarray(st.shadow_db)
    assert np.allclose(sh, sh.T)


def test_csi_perturbation_mean_one():
    f = csi_perturbation(jax.random.PRNGKey(0), 4096, 0.3)
    assert abs(float(f.mean()) - 1.0) < 0.05
    assert float(f.min()) > 0.0


# ---------------------------------------------------------------------------
# Scheduling.
# ---------------------------------------------------------------------------

def test_trivial_schedule_flags():
    assert ScheduleConfig().is_trivial
    assert not ScheduleConfig(dropout_prob=0.1).is_trivial
    assert not ScheduleConfig(num_stragglers=2, straggler_period=3).is_trivial
    assert not ScheduleConfig(energy_budget=5).is_trivial
    # stragglers without a period never fire
    assert ScheduleConfig(num_stragglers=2).is_trivial


def test_full_dropout_gives_empty_mask():
    cfg = ScheduleConfig(dropout_prob=1.0)
    st = init_schedule(cfg, K)
    mask, st = participation_mask(cfg, st, jnp.asarray(0), jax.random.PRNGKey(0), K)
    assert float(mask.sum()) == 0.0


def test_stragglers_follow_the_period():
    cfg = ScheduleConfig(num_stragglers=3, straggler_period=3)
    st = init_schedule(cfg, K)
    masks = []
    for t in range(6):
        m, st = participation_mask(cfg, st, jnp.asarray(t),
                                   jax.random.PRNGKey(t), K)
        masks.append(np.asarray(m))
    for t, m in enumerate(masks):
        expect_drop = (t % 3) == 2
        assert (m[:3] == (0.0 if expect_drop else 1.0)).all()
        assert (m[3:] == 1.0).all()


def test_energy_budget_exhausts():
    cfg = ScheduleConfig(energy_budget=2)
    st = init_schedule(cfg, K)
    sums = []
    for t in range(4):
        m, st = participation_mask(cfg, st, jnp.asarray(t),
                                   jax.random.PRNGKey(t), K)
        sums.append(float(m.sum()))
    assert sums[:2] == [K, K] and sums[2:] == [0.0, 0.0]


# ---------------------------------------------------------------------------
# Mask-aware renormalization of the round coefficients.
# ---------------------------------------------------------------------------

def _cwfl_state(topo):
    return cwfl.setup(topo, cwfl.CWFLConfig(num_clusters=3, snr_db=40.0),
                      jax.random.PRNGKey(5))


def test_masked_coefficients_renormalize(topo):
    state = _cwfl_state(topo)
    params = {"w": jax.random.normal(jax.random.PRNGKey(6), (K, 32))}
    mask = jnp.ones((K,)).at[jnp.asarray([1, 4])].set(0.0)
    A, std1, B, kappa, m_back = cwfl.round_coefficients(
        state, params, mask=mask)
    A_np = np.asarray(A)
    head_mask = np.asarray(state.plan.head_mask)
    for k in (1, 4):
        if head_mask[k] == 0:          # heads are forced present
            assert np.allclose(A_np[:, k], 0.0)
    np.testing.assert_allclose(A_np.sum(axis=1), 1.0, atol=1e-5)

    # fewer participants ⇒ the renormalized receiver noise can only grow
    _, std1_full, *_ = cwfl.round_coefficients(state, params, mask=None)
    assert (np.asarray(std1) >= np.asarray(std1_full) - 1e-9).all()


def test_all_ones_mask_is_bit_identical(topo):
    """Satellite: the participation-mask path with an all-ones mask equals
    the unmasked path bit-for-bit (CWFL and COTAF)."""
    from repro.core import baselines as bl
    state = _cwfl_state(topo)
    params = {"w": jax.random.normal(jax.random.PRNGKey(8), (K, 640)),
              "b": jax.random.normal(jax.random.PRNGKey(9), (K, 7))}
    key = jax.random.PRNGKey(10)
    ones = jnp.ones((K,))
    new_m, cons_m = cwfl.aggregate(params, state, key, mask=ones)
    new_u, cons_u = cwfl.aggregate(params, state, key, mask=None)
    for a, b in zip(jax.tree.leaves((new_m, cons_m)),
                    jax.tree.leaves((new_u, cons_u))):
        assert bool(jnp.array_equal(a, b))

    cstate = bl.cotaf_setup(topo, jax.random.PRNGKey(11), snr_db=40.0)
    for a, b in zip(
            jax.tree.leaves(bl.cotaf_aggregate(params, cstate, key,
                                               mask=ones)),
            jax.tree.leaves(bl.cotaf_aggregate(params, cstate, key))):
        assert bool(jnp.array_equal(a, b))


def test_masked_aggregate_zeroes_absent_contribution(topo):
    """An absent member's parameters must not influence the OTA sum: make
    one non-head client's params huge; with it masked out the round output
    matches the run where that client held ordinary values."""
    state = _cwfl_state(topo)
    absent = int(np.flatnonzero(np.asarray(state.plan.head_mask) == 0)[0])
    key = jax.random.PRNGKey(12)
    base = jax.random.normal(jax.random.PRNGKey(13), (K, 64))
    huge = base.at[absent].set(1e6)
    mask = jnp.ones((K,)).at[absent].set(0.0)
    _, cons_huge = cwfl.aggregate({"w": huge}, state, key, mask=mask,
                                  precode=False)
    _, cons_base = cwfl.aggregate({"w": base}, state, key, mask=mask,
                                  precode=False)
    np.testing.assert_allclose(np.asarray(cons_huge["w"]),
                               np.asarray(cons_base["w"]), atol=1e-5)
