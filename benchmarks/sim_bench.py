"""Scenario-engine benchmarks: scanned trajectory vs legacy per-round loop.

Measures, on the tiny-MLP CPU workload (same protocol as the paper's §V,
scaled):

* ``sim_scan``  — one full trajectory as a single jitted ``lax.scan``
  (no per-round host sync, metrics in on-device buffers);
* ``sim_loop``  — the pre-engine structure: one jitted round, Python loop,
  ``float(loss)`` host sync per round;
* ``sim_mc``    — the Monte-Carlo grid (seeds × SNR sweep) compiled as ONE
  jit, reporting aggregate rounds/sec throughput;
* ``sim_mc_vmap_S8`` / ``sim_mc_sharded_S8`` — the 8-trajectory A/B of
  the single-device vmap sweep against the ``shard_map`` trajectory-
  parallel sweep (`repro.sim.sharded`, ``mc`` mesh axis): steady-state
  trajectory throughput, compile seconds, speedup, and a bitwise parity
  bit (needs > 1 visible device; CI fakes 8 on CPU).

``benchmarks/run.py --only sim`` persists the rows to ``BENCH_sim.json``
(rounds/sec, scan-vs-loop speedup, MC + sharded throughput) so the speed
trajectory is machine-comparable across PRs — gate a fresh file against
the committed baseline with ``benchmarks/compare.py``.  Jitted rows are
timed through an AOT trace/compile/execute split
(`repro.obs.profiling.PhaseTimers`) recorded per-row as ``phases``;
``compile_seconds`` is kept as trace+compile for baseline continuity.
"""
from __future__ import annotations

import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np


def _median_time(fn, n: int = 3) -> float:
    """Median wall seconds over ``n`` calls (callers warm up first)."""
    samples = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        samples.append(time.perf_counter() - t0)
    return statistics.median(samples)


def _aot_phases(jitted, *args):
    """AOT-split a jitted callable via `repro.obs.profiling.PhaseTimers`:
    trace (``lower``), compile, first execute — the split the hand-rolled
    "compile + run" wall figure used to lump together.  Returns
    ``(compiled, phases_dict)``; ``phases["trace"] + phases["compile"]``
    is the old ``compile_seconds``."""
    from repro.obs.profiling import PhaseTimers
    timers = PhaseTimers()
    with timers.phase("trace"):
        lowered = jitted.lower(*args)
    with timers.phase("compile"):
        compiled = lowered.compile()
    with timers.phase("execute"):
        jax.block_until_ready(compiled(*args))
    return compiled, timers.as_dict()


def run(rounds: int = 8, mc_rounds: int = 3, seeds: int = 2,
        clients: int = 8, hidden: int = 32, train: int = 960,
        test: int = 512, snr_grid=(0.0, 20.0, 40.0)):
    """Returns a list of row dicts: name, us, derived + JSON extras.

    ``rounds`` drives the scan-vs-loop A/B (long enough that the scan's
    fixed setup amortizes — at T≲3 the per-round host dispatch the scan
    removes is in the measurement noise); ``mc_rounds``/``seeds`` size
    the Monte-Carlo sweep (CI smoke: 3 rounds × 2 seeds × SNR grid).
    """
    from repro.core import TopologyConfig, make_topology
    from repro.data import (SyntheticImageConfig, make_synthetic_images,
                            partition_iid)
    from repro.launch.mesh import make_mc_mesh
    from repro.models import make_mnist_mlp, nll_loss
    from repro.sim.engine import _SCAN_UNROLL, _build, make_trajectory_fn
    from repro.sim.scenarios import Scenario
    from repro.sim.sharded import make_sharded_sweep_fn
    from repro.training import FLConfig

    tcfg = TopologyConfig(num_clients=clients, num_hotspots=3)
    topo = make_topology(jax.random.PRNGKey(7), tcfg)
    dcfg = SyntheticImageConfig.mnist_like(train, test)
    (xtr, ytr), (xte, yte) = make_synthetic_images(jax.random.PRNGKey(1),
                                                   dcfg)
    xs, ys = partition_iid(jax.random.PRNGKey(2), xtr, ytr, clients)
    init, apply = make_mnist_mlp(hidden=(hidden,))
    loss = lambda p, x, y: nll_loss(apply(p, x), y)
    cfg = FLConfig(strategy="cwfl", rounds=rounds, snr_db=40.0,
                   eval_samples=test)
    tag = f"K{clients}_T{rounds}"
    rows = []

    prepare, make_body = _build(init, apply, loss, topo, xs, ys, xte, yte,
                                cfg, Scenario(), tcfg)
    ctx, carry0, scan_xs = prepare(cfg.seed, cfg.snr_db)
    body = make_body(ctx)

    # --- scanned trajectory (one jit, no per-round host sync) -------------
    scan_f, scan_phases = _aot_phases(
        jax.jit(lambda c, x: jax.lax.scan(body, c, x,
                                          unroll=_SCAN_UNROLL)),
        carry0, scan_xs)
    scan_s = _median_time(
        lambda: jax.block_until_ready(scan_f(carry0, scan_xs)))
    scan_rps = rounds / scan_s

    # --- legacy per-round loop (jitted round, host loop + float() sync) ---
    body_j = jax.jit(body)
    inp0 = jax.tree.map(lambda x: x[0], scan_xs)
    jax.block_until_ready(body_j(carry0, inp0))             # compile

    def loop_once():
        c = carry0
        for t in range(rounds):
            inp = jax.tree.map(lambda x: x[t], scan_xs)
            c, (l, a) = body_j(c, inp)
            float(l), float(a)                              # per-round sync
    loop_s = _median_time(loop_once)
    loop_rps = rounds / loop_s
    speedup = loop_s / scan_s

    rows.append({"name": f"sim_scan_{tag}", "us": scan_s * 1e6,
                 "derived": f"rps={scan_rps:.2f};speedup_vs_loop="
                            f"{speedup:.2f}x",
                 "rounds_per_sec": scan_rps,
                 "speedup_vs_loop": speedup,
                 "compile_seconds": scan_phases["trace"]
                                    + scan_phases["compile"],
                 "phases": scan_phases,
                 "rounds": rounds})
    rows.append({"name": f"sim_loop_{tag}", "us": loop_s * 1e6,
                 "derived": f"rps={loop_rps:.2f}",
                 "rounds_per_sec": loop_rps,
                 "rounds": rounds})

    # --- Monte-Carlo grid: seeds × SNR sweep in ONE jit -------------------
    grid = jnp.asarray(snr_grid, jnp.float32)
    mc_cfg = FLConfig(strategy="cwfl", rounds=mc_rounds, snr_db=40.0,
                      eval_samples=test)
    mc_prepare, mc_make_body = _build(init, apply, loss, topo, xs, ys, xte,
                                      yte, mc_cfg, Scenario(), tcfg)
    traj = make_trajectory_fn(mc_prepare, mc_make_body)

    seed_arr = jnp.arange(seeds)
    mc_f, mc_phases = _aot_phases(
        jax.jit(jax.vmap(jax.vmap(traj, in_axes=(None, 0)),
                         in_axes=(0, None))),
        seed_arr, grid)
    mc_s = _median_time(lambda: jax.block_until_ready(mc_f(seed_arr, grid)))
    n_traj = seeds * int(grid.shape[0])
    mc_rps = n_traj * mc_rounds / mc_s
    rows.append({"name": f"sim_mc_S{seeds}_G{int(grid.shape[0])}"
                         f"_K{clients}_T{mc_rounds}",
                 "us": mc_s * 1e6,
                 "derived": f"traj={n_traj};mc_rps={mc_rps:.2f}",
                 "trajectories": n_traj,
                 "mc_rounds_per_sec": mc_rps,
                 "compile_seconds": mc_phases["trace"] + mc_phases["compile"],
                 "phases": mc_phases,
                 "snr_grid": np.asarray(grid).tolist(),
                 "rounds": mc_rounds})

    # --- sharded vs vmap: 8 trajectories across the device mesh -----------
    # The acceptance A/B for `repro.sim.sharded`: same traced trajectory
    # body, batched on one device (vmap) vs distributed over the ("mc",)
    # mesh (shard_map).  Steady-state (post-compile) throughput; the
    # seeds-only sweep is bitwise-identical between the two executors.
    # The mc axis must divide the 8 trajectories or fit_spec would fall
    # back to replication and the row would measure redundant unsharded
    # work — cap the mesh to the largest dividing device count.
    n_dev = next(n for n in (8, 4, 2, 1) if n <= len(jax.devices()))
    if n_dev > 1:
        seeds8 = jnp.arange(8)
        vmap_f, vmap_phases = _aot_phases(
            jax.jit(jax.vmap(traj, in_axes=(0, None))), seeds8, 40.0)
        vmap_s = _median_time(
            lambda: jax.block_until_ready(vmap_f(seeds8, 40.0)))

        mesh = make_mc_mesh(n_dev)
        shard_f, shard_phases = _aot_phases(
            make_sharded_sweep_fn(traj, 8, mc_rounds, mesh, snr_db=40.0),
            seeds8)
        shard_s = _median_time(
            lambda: jax.block_until_ready(shard_f(seeds8)))

        bitwise = all(
            bool(jnp.array_equal(a, b))
            for a, b in zip(vmap_f(seeds8, 40.0), shard_f(seeds8)))
        traj_speedup = vmap_s / shard_s
        rows.append({"name": f"sim_mc_vmap_S8_K{clients}_T{mc_rounds}",
                     "us": vmap_s * 1e6,
                     "derived": f"traj_per_sec={8 / vmap_s:.2f}",
                     "traj_per_sec": 8 / vmap_s,
                     "compile_seconds": vmap_phases["trace"]
                                        + vmap_phases["compile"],
                     "phases": vmap_phases,
                     "rounds": mc_rounds})
        rows.append({"name": f"sim_mc_sharded_S8_D{n_dev}_K{clients}"
                             f"_T{mc_rounds}",
                     "us": shard_s * 1e6,
                     "derived": f"traj_per_sec={8 / shard_s:.2f};"
                                f"speedup_vs_vmap={traj_speedup:.2f}x;"
                                f"bitwise={bitwise}",
                     "traj_per_sec": 8 / shard_s,
                     "speedup_vs_vmap": traj_speedup,
                     "bitwise_equal_vs_vmap": bitwise,
                     "devices": n_dev,
                     "compile_seconds": shard_phases["trace"]
                                        + shard_phases["compile"],
                     "phases": shard_phases,
                     "rounds": mc_rounds})
    return rows
