"""Channel-use efficiency (the paper's headline claim §IV/VI): CWFL needs
C(C−1) head-to-head uses + C intra-cluster OTA slots per round, vs K(K−1)
for fully-decentralized consensus and 1 for a (single) server OTA MAC.

Counts come from `repro.obs.ledger.per_round_table` — the same
`Strategy.channel_uses` arithmetic the in-scan telemetry ledger
accumulates, so this table and a run's recorded ``cum_channel_uses`` are
one source of truth."""
from __future__ import annotations

from repro.obs.ledger import per_round_table


def run(clients=(12, 27, 50, 100), clusters=(2, 3, 4, 8)):
    rows = []
    for K in clients:
        for C in clusters:
            if C >= K:
                continue
            u = per_round_table(K, C)
            rows.append({"K": K, "C": C, **u,
                         "saving_vs_decentralized":
                             u["decentralized"] / u["cwfl"]})
    return rows
