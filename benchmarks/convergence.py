"""Theorem 1 empirically: per-cluster O(1/T) error decay on a strongly-convex
quadratic, with the SNR-dependent Q₂ floor."""
from __future__ import annotations

import numpy as np

from tests.test_convergence import run_cwfl_quadratic


def run(T: int = 150):
    out = {}
    for snr in (10.0, 20.0, 40.0):
        errs = run_cwfl_quadratic(T=T, snr_db=snr)
        # fit err ≈ a / (t + b) + c on the tail
        t = np.arange(1, T + 1)
        rate = errs[T // 4] / max(errs[-1], 1e-12)
        out[f"snr{int(snr)}"] = {
            "err_T4": float(errs[T // 4]),
            "err_T": float(errs[-1]),
            "decay_T4_to_T": float(rate),
            "floor": float(np.mean(errs[-10:])),
        }
    return out
