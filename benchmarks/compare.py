"""Bench regression gate: compare a fresh BENCH_*.json against a baseline.

    python benchmarks/compare.py BENCH_sim.json /tmp/fresh_sim.json \
        --max-regression 0.75 --markdown delta.md

Walks the benchmark entries both files share and gates three ways:

* **throughput** (higher-better: ``rounds_per_sec``, ``traj_per_sec``,
  ``mc_rounds_per_sec``) and **latency** (lower-better: ``us``) regress
  when the fresh value is worse than baseline by more than
  ``--max-regression`` (a ratio; the default 0.5 = 50% tolerates this
  hardware's run-to-run noise, CI uses a still-looser gate — these
  benches share cores with the rest of the job);
* **deterministic** fields (``modeled_hbm_bytes``, ``jaxpr_identical``,
  ``bitwise_equal_vs_vmap``) must match EXACTLY — a drifted byte model
  or a lost bitwise-equality invariant is a correctness bug no noise
  argument excuses;
* everything else (``compile_seconds``, ``speedup_*``, ``derived``,
  phase splits) is reported in the delta table but never gates.

Meta entries (``run_manifest``, ``throughput_vs_previous_file``) are
provenance, not benchmarks, and are skipped.  Exit 0 = green, 1 = at
least one gate tripped, 2 = usage error / nothing to compare (an empty
intersection means the key sets drifted — that fails loudly rather than
vacuously passing).  ``--markdown`` writes the delta table for a CI job
summary.  Stdlib only.
"""
from __future__ import annotations

import argparse
import json
import sys

HIGHER_BETTER = ("rounds_per_sec", "traj_per_sec", "mc_rounds_per_sec")
LOWER_BETTER = ("us",)
EXACT = ("modeled_hbm_bytes", "jaxpr_identical", "bitwise_equal_vs_vmap")
META_KEYS = ("run_manifest", "throughput_vs_previous_file")


def compare(baseline: dict, fresh: dict, max_regression: float) -> dict:
    """Compare two bench dicts; returns {rows, failures, matched}."""
    rows, failures = [], []
    matched = 0
    for name in sorted(set(baseline) & set(fresh)):
        if name in META_KEYS:
            continue
        b, f = baseline[name], fresh[name]
        if not (isinstance(b, dict) and isinstance(f, dict)):
            continue
        matched += 1
        for field in sorted(set(b) & set(f)):
            bv, fv = b[field], f[field]
            if field in EXACT:
                ok = bv == fv
                rows.append((name, field, bv, fv, "exact",
                             "ok" if ok else "FAIL"))
                if not ok:
                    failures.append(f"{name}.{field}: baseline {bv!r} "
                                    f"!= fresh {fv!r} (exact-match field)")
            elif field in HIGHER_BETTER and _num(bv) and _num(fv):
                ratio = fv / bv if bv else float("inf")
                ok = fv >= bv * (1.0 - max_regression)
                rows.append((name, field, bv, fv, f"{ratio:.2f}x",
                             "ok" if ok else "FAIL"))
                if not ok:
                    failures.append(
                        f"{name}.{field}: {fv:.2f} vs baseline {bv:.2f} "
                        f"({ratio:.2f}x < allowed "
                        f"{1.0 - max_regression:.2f}x)")
            elif field in LOWER_BETTER and _num(bv) and _num(fv):
                ratio = fv / bv if bv else float("inf")
                ok = fv <= bv * (1.0 + max_regression)
                rows.append((name, field, bv, fv, f"{ratio:.2f}x",
                             "ok" if ok else "FAIL"))
                if not ok:
                    failures.append(
                        f"{name}.{field}: {fv:.2f}us vs baseline "
                        f"{bv:.2f}us ({ratio:.2f}x > allowed "
                        f"{1.0 + max_regression:.2f}x)")
            elif _num(bv) and _num(fv) and bv:
                rows.append((name, field, bv, fv, f"{fv / bv:.2f}x",
                             "info"))
    return {"rows": rows, "failures": failures, "matched": matched}


def _num(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def markdown_table(result: dict, title: str) -> str:
    lines = [f"### Bench delta: {title}", "",
             "| bench | metric | baseline | fresh | ratio | gate |",
             "|---|---|---:|---:|---:|---|"]
    for name, field, bv, fv, ratio, status in result["rows"]:
        mark = {"ok": "✅", "FAIL": "❌", "info": "—"}[status]
        lines.append(f"| {name} | {field} | {_fmt(bv)} | {_fmt(fv)} "
                     f"| {ratio} | {mark} |")
    lines.append("")
    if result["failures"]:
        lines.append(f"**{len(result['failures'])} gate(s) tripped:**")
        lines += [f"- {f}" for f in result["failures"]]
    else:
        lines.append(f"All gates green over {result['matched']} "
                     f"matched benches.")
    lines.append("")
    return "\n".join(lines)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="committed baseline BENCH_*.json")
    ap.add_argument("fresh", help="freshly generated BENCH_*.json")
    ap.add_argument("--max-regression", type=float, default=0.5,
                    help="allowed fractional throughput/latency "
                         "regression (0.5 = 50%%)")
    ap.add_argument("--markdown", default=None,
                    help="write the delta table to this markdown file "
                         "(CI job summary)")
    ap.add_argument("--label", default=None,
                    help="table title (default: the fresh path)")
    args = ap.parse_args()

    try:
        with open(args.baseline) as fh:
            baseline = json.load(fh)
        with open(args.fresh) as fh:
            fresh = json.load(fh)
    except (OSError, ValueError) as e:
        print(f"compare.py: cannot load inputs: {e}", file=sys.stderr)
        return 2
    if not (isinstance(baseline, dict) and isinstance(fresh, dict)):
        print("compare.py: BENCH files must be JSON objects",
              file=sys.stderr)
        return 2

    result = compare(baseline, fresh, args.max_regression)
    table = markdown_table(result, args.label or args.fresh)
    print(table)
    if args.markdown:
        with open(args.markdown, "w") as fh:
            fh.write(table)
    if result["matched"] == 0:
        print("compare.py: no matched benchmark entries — key sets "
              "drifted?", file=sys.stderr)
        return 2
    return 1 if result["failures"] else 0


if __name__ == "__main__":
    sys.exit(main())
