"""Figure 2: accuracy vs communication rounds — IID and non-IID, MNIST-like
and CIFAR-like, CWFL-{3,4} vs COTAF (+FedAvg upper bound)."""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import BenchScale, run_setting


SETTINGS = [
    # (dataset, iid, strategy, clusters, prox, label)
    ("mnist", True, "cwfl", 3, 0.0, "CWFL-3"),
    ("mnist", True, "cwfl", 4, 0.0, "CWFL-4"),
    ("mnist", True, "cotaf", 3, 0.0, "COTAF"),
    ("mnist", True, "fedavg", 3, 0.0, "FedAvg(ideal)"),
    ("mnist", False, "cwfl", 3, 0.0, "CWFL-3"),
    ("mnist", False, "cwfl", 3, 0.1, "CWFL-3-Prox"),
    ("mnist", False, "cotaf", 3, 0.0, "COTAF"),
    ("cifar", True, "cwfl", 3, 0.0, "CWFL-3"),
    ("cifar", True, "cotaf", 3, 0.0, "COTAF"),
    ("cifar", False, "cwfl", 3, 0.0, "CWFL-3"),
    ("cifar", False, "cwfl", 3, 0.1, "CWFL-3-Prox"),
    ("cifar", False, "cotaf", 3, 0.0, "COTAF"),
]


def run(scale: BenchScale, out_path="results/fig2.json", subset=None):
    rows = []
    settings = SETTINGS if subset is None else SETTINGS[:subset]
    for ds, iid, strat, C, prox, label in settings:
        h = run_setting(ds, iid, strat, scale, num_clusters=C, mu_prox=prox)
        rows.append({
            "dataset": ds, "iid": iid, "label": label,
            "acc_curve": h["test_acc"], "avg_acc": h["avg_acc"],
            "final_acc": h["final_acc"],
            "seconds_per_round": h["seconds_per_round"],
        })
        print(f"  fig2 {ds} {'iid' if iid else 'noniid'} {label}: "
              f"final={h['final_acc']:.3f} avg={h['avg_acc']:.3f}")
    Path(out_path).parent.mkdir(parents=True, exist_ok=True)
    Path(out_path).write_text(json.dumps(rows, indent=1))
    return rows
