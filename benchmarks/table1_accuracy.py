"""Table I: average non-IID accuracy — COTAF / COTAF-Prox / CWFL-3 /
CWFL-3-Prox / CWFL-4 (MNIST; CWFL-4 omitted for CIFAR as in the paper)."""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import BenchScale, run_setting

ROWS = [
    ("COTAF", "cotaf", 3, 0.0),
    ("COTAF-Prox", "cotaf", 3, 0.1),
    ("CWFL-3", "cwfl", 3, 0.0),
    ("CWFL-3-Prox", "cwfl", 3, 0.1),
    ("CWFL-4", "cwfl", 4, 0.0),
]


def run(scale: BenchScale, out_path="results/table1.json",
        datasets=("mnist", "cifar")):
    table = {}
    for ds in datasets:
        table[ds] = {}
        for label, strat, C, prox in ROWS:
            if ds == "cifar" and label == "CWFL-4":
                table[ds][label] = None      # paper: '-'
                continue
            h = run_setting(ds, False, strat, scale, num_clusters=C,
                            mu_prox=prox)
            table[ds][label] = h["avg_acc"]
            print(f"  table1 {ds} {label}: avg={h['avg_acc']:.3f}")
    Path(out_path).parent.mkdir(parents=True, exist_ok=True)
    Path(out_path).write_text(json.dumps(table, indent=1))
    return table
