"""Shared benchmark plumbing: the paper's experiment grid, scaled for CPU.

``fast`` (default) runs a reduced-but-faithful version of §V: fewer clients /
samples / rounds, same protocol, same relative claims. ``--full`` restores
the paper's sizes (K=50/27, 60k/50k samples, 70-80 rounds) — hours on 1 CPU.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.core import TopologyConfig, make_topology
from repro.data import (SyntheticImageConfig, make_synthetic_images,
                        partition_iid, partition_noniid)
from repro.models import make_cifar_cnn, make_mnist_mlp, nll_loss
from repro.training import FLConfig, run_federated


@dataclasses.dataclass
class BenchScale:
    mnist_clients: int = 20
    cifar_clients: int = 9
    mnist_train: int = 6000
    cifar_train: int = 1350
    test: int = 1200
    rounds: int = 22
    eval_samples: int = 1024
    mnist_shards_per_client: int = 4
    cifar_shards_per_client: int = 7

    @staticmethod
    def full() -> "BenchScale":
        return BenchScale(mnist_clients=50, cifar_clients=27,
                          mnist_train=60000, cifar_train=50000, test=10000,
                          rounds=70, eval_samples=4096)


def make_dataset(name: str, scale: BenchScale, key):
    if name == "mnist":
        cfg = SyntheticImageConfig.mnist_like(scale.mnist_train, scale.test)
        K = scale.mnist_clients
        spc = scale.mnist_shards_per_client
        init, apply = make_mnist_mlp()
        batch = 64
    else:
        cfg = SyntheticImageConfig.cifar_like(scale.cifar_train, scale.test)
        K = scale.cifar_clients
        spc = scale.cifar_shards_per_client
        init, apply = make_cifar_cnn()
        batch = 32
    (xtr, ytr), (xte, yte) = make_synthetic_images(key, cfg)
    return dict(x=xtr, y=ytr, x_test=xte, y_test=yte, K=K,
                shards_per_client=spc, init=init, apply=apply, batch=batch)


def run_setting(name: str, iid: bool, strategy: str, scale: BenchScale, *,
                num_clusters: int = 3, mu_prox: float = 0.0,
                seed: int = 0, snr_db: float = 40.0):
    """One Fig-2 curve. Returns (history, seconds_per_round)."""
    key = jax.random.PRNGKey(seed)
    data = make_dataset(name, scale, key)
    K = data["K"]
    topo = make_topology(jax.random.PRNGKey(seed + 7),
                         TopologyConfig(num_clients=K,
                                        num_hotspots=max(num_clusters, 3)))
    if iid:
        xs, ys = partition_iid(jax.random.PRNGKey(seed + 1),
                               data["x"], data["y"], K)
    else:
        # paper: 200 shards; scaled runs reduce shard count proportionally
        num_shards = max(K * data["shards_per_client"], 40)
        xs, ys = partition_noniid(jax.random.PRNGKey(seed + 1),
                                  data["x"], data["y"], K,
                                  data["shards_per_client"],
                                  num_shards=num_shards)
    loss = lambda p, x, y: nll_loss(data["apply"](p, x), y)
    cfg = FLConfig(strategy=strategy, rounds=scale.rounds,
                   batch_size=data["batch"], num_clusters=num_clusters,
                   snr_db=snr_db, mu_prox=mu_prox,
                   eval_samples=scale.eval_samples, seed=seed)
    t0 = time.time()
    h = run_federated(data["init"], data["apply"], loss, topo, xs, ys,
                      data["x_test"], data["y_test"], cfg)
    h["seconds_per_round"] = (time.time() - t0) / scale.rounds
    return h
