"""Kernel micro-benchmarks: interpret-mode Pallas vs pure-jnp oracle.

NOTE: on this CPU-only container the Pallas kernels execute in interpret
mode (python), so wall-clock favors the jnp oracle — the numbers here are
correctness/latency bookkeeping, not TPU performance. The TPU-relevant
analysis is the VMEM/blocking design (DESIGN.md §4) and the roofline.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.ops import ota_aggregate_op
from repro.kernels.ota_aggregate import ota_aggregate
from repro.kernels.flash_attention import flash_attention as fa_kernel
from repro.kernels.ref import flash_attention_ref, ota_aggregate_ref


def _time(f, *args, n=3):
    f(*args)  # compile/warm
    t0 = time.time()
    for _ in range(n):
        jax.block_until_ready(f(*args))
    return (time.time() - t0) / n * 1e6   # us


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    # OTA aggregate: paper-scale K=50 clients, d = MNIST-MLP params (~180k)
    s = jax.random.normal(key, (50, 180000))
    w = jax.random.uniform(jax.random.PRNGKey(1), (3, 50))
    n = 0.1 * jax.random.normal(jax.random.PRNGKey(2), (3, 180000))
    rows.append(("ota_aggregate_pallas_interp",
                 _time(lambda: ota_aggregate(s, w, n, tile=2048))))
    rows.append(("ota_aggregate_jnp_ref",
                 _time(lambda: ota_aggregate_ref(s, w, n))))

    q = jax.random.normal(key, (1, 4, 512, 64))
    k = jax.random.normal(jax.random.PRNGKey(3), (1, 2, 512, 64))
    v = jax.random.normal(jax.random.PRNGKey(4), (1, 2, 512, 64))
    rows.append(("flash_attention_pallas_interp",
                 _time(lambda: fa_kernel(q, k, v, block_q=128, block_k=128))))
    rows.append(("flash_attention_jnp_ref",
                 _time(lambda: flash_attention_ref(q, k, v))))
    return rows
