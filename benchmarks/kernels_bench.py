"""Kernel micro-benchmarks: interpret-mode Pallas vs pure-jnp oracle.

NOTE: on this CPU-only container the Pallas kernels execute in interpret
mode (python), so wall-clock favors the jnp oracle — the numbers here are
correctness/latency bookkeeping, not TPU performance. The TPU-relevant
analysis is the VMEM/blocking design (DESIGN.md §4/§8), the roofline, and
the modeled HBM traffic of the fused round (``hbm_bytes_model``), which
``benchmarks/run.py`` persists to ``BENCH_kernels.json`` so the perf
trajectory stays machine-readable across PRs.

The XLA-compiled round variants also record the compiler's own
``cost_analysis()`` "bytes accessed" next to ``modeled_hbm_bytes`` with
a >20% model-vs-measured drift flag (informational on CPU — XLA fuses
and pads differently than the TPU HBM accounting the model targets; the
interpret-mode Pallas row has no XLA executable to measure).  Gate a
fresh file against the committed baseline with
``benchmarks/compare.py``.
"""
from __future__ import annotations

import statistics
import time

import jax
import jax.numpy as jnp

from repro.kernels.cwfl_round import cwfl_round, hbm_bytes_model
from repro.kernels.flash_attention import flash_attention as fa_kernel
from repro.kernels.ota_aggregate import ota_aggregate
from repro.kernels.ref import (cwfl_round_ref, flash_attention_ref,
                               ota_aggregate_ref)

# Paper-scale round: K=50 clients, C=3 clusters, d = MNIST-MLP params.
ROUND_K, ROUND_C, ROUND_D = 50, 3, 180000


def _time(f, *args, n: int = 5, warmup: int = 2) -> float:
    """Median wall time in µs over ``n`` timed calls after ``warmup``
    compile/cache runs (``time.perf_counter``: monotonic, high-res)."""
    for _ in range(warmup):
        jax.block_until_ready(f(*args))
    samples = []
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(f(*args))
        samples.append((time.perf_counter() - t0) * 1e6)
    return statistics.median(samples)


def _xla_bytes(fn, *args):
    """XLA's measured ``bytes accessed`` for ``jit(fn)(*args)`` via the
    compiled executable's ``cost_analysis()`` (None when the backend
    reports nothing).  The empirical cross-check on the analytic
    ``hbm_bytes_model``: same dataflow, counted by the compiler instead
    of by hand."""
    from repro.utils.jaxcompat import cost_analysis_dict
    compiled = jax.jit(fn).lower(*args).compile()
    val = cost_analysis_dict(compiled).get("bytes accessed")
    return None if val is None else int(val)


def _drift_tag(modeled: int, measured) -> dict:
    """``xla_bytes_accessed`` next to the model, plus a >20% drift flag —
    informational on CPU, where XLA fuses/pads differently than the TPU
    HBM accounting the model targets."""
    if not measured:
        return {"xla_bytes_accessed": measured}
    drift = abs(measured - modeled) / modeled
    return {"xla_bytes_accessed": measured,
            "model_vs_xla_drift": round(drift, 4),
            "model_vs_xla_drift_over_20pct": bool(drift > 0.20)}


def _three_pass_round():
    """The unfused baseline: each phase a separate jitted call, so every
    intermediate (θ̃, θ̄) round-trips through device memory — the traffic
    pattern the fused kernel removes.  Broadcast and consensus are
    separate passes (θ̄ read twice), matching ``hbm_bytes_model``'s
    5·C·d accounting for the unfused round."""
    p1 = jax.jit(lambda a, s, n: a @ s + n)
    p2 = jax.jit(lambda b, tt, n: b @ tt + n)
    p3 = jax.jit(lambda m, tb: m @ tb)
    p4 = jax.jit(lambda tb: jnp.mean(tb, axis=0))

    def run(s, a, n1, b, n2, m):
        theta_tilde = p1(a, s, n1)
        theta_bar = p2(b, theta_tilde, n2)
        return p3(m, theta_bar), p4(theta_bar)

    return run


def run():
    """Returns a list of row dicts: name, us, derived, plus machine-
    readable extras (modeled HBM bytes for the round variants)."""
    rows = []
    key = jax.random.PRNGKey(0)
    K, C, d = ROUND_K, ROUND_C, ROUND_D

    s = jax.random.normal(key, (K, d))
    a = jax.random.uniform(jax.random.PRNGKey(1), (C, K))
    n1 = 0.1 * jax.random.normal(jax.random.PRNGKey(2), (C, d))
    b = jax.random.uniform(jax.random.PRNGKey(3), (C, C))
    n2 = 0.1 * jax.random.normal(jax.random.PRNGKey(4), (C, d))
    m = jax.random.uniform(jax.random.PRNGKey(5), (K, C))

    traffic = hbm_bytes_model(K, C, d)
    shape_tag = f"K{K}_C{C}_d{d}"

    fused_us = _time(lambda: cwfl_round(s, a, n1, b, n2, m, tile=2048))
    rows.append({
        "name": "cwfl_round_fused_pallas_interp", "us": fused_us,
        "derived": f"{shape_tag};interpret-mode",
        "modeled_hbm_bytes": traffic["fused_bytes"],
    })

    three_pass = _three_pass_round()
    unfused_us = _time(lambda: three_pass(s, a, n1, b, n2, m))
    # Measured counterpart of the 5·C·d unfused accounting: each pass is
    # its own XLA executable, so its intermediates round-trip through
    # memory exactly as the model assumes — sum the per-pass figures.
    theta_tilde = a @ s + n1
    theta_bar = b @ theta_tilde + n2
    unfused_xla = [
        _xla_bytes(lambda A, S, N: A @ S + N, a, s, n1),
        _xla_bytes(lambda B, TT, N: B @ TT + N, b, theta_tilde, n2),
        _xla_bytes(lambda M, TB: M @ TB, m, theta_bar),
        _xla_bytes(lambda TB: jnp.mean(TB, axis=0), theta_bar),
    ]
    unfused_meas = (None if any(v is None for v in unfused_xla)
                    else sum(unfused_xla))
    rows.append({
        "name": "cwfl_round_three_pass_baseline", "us": unfused_us,
        "derived": (f"{shape_tag};"
                    f"traffic_ratio={traffic['traffic_ratio']:.2f}x"),
        "modeled_hbm_bytes": traffic["unfused_bytes"],
        **_drift_tag(traffic["unfused_bytes"], unfused_meas),
    })

    fused_jnp_us = _time(lambda: cwfl_round_ref(s, a, n1, b, n2, m))
    rows.append({
        "name": "cwfl_round_jnp_ref", "us": fused_jnp_us,
        "derived": f"{shape_tag};single-jit",
        "modeled_hbm_bytes": traffic["fused_bytes"],
        **_drift_tag(traffic["fused_bytes"],
                     _xla_bytes(cwfl_round_ref, s, a, n1, b, n2, m)),
    })

    rows.append({
        "name": "ota_aggregate_pallas_interp",
        "us": _time(lambda: ota_aggregate(s, a, n1, tile=2048)),
        "derived": "interpret-mode"})
    rows.append({
        "name": "ota_aggregate_jnp_ref",
        "us": _time(lambda: ota_aggregate_ref(s, a, n1)),
        "derived": "-"})

    q = jax.random.normal(key, (1, 4, 512, 64))
    k = jax.random.normal(jax.random.PRNGKey(6), (1, 2, 512, 64))
    v = jax.random.normal(jax.random.PRNGKey(7), (1, 2, 512, 64))
    rows.append({
        "name": "flash_attention_pallas_interp",
        "us": _time(lambda: fa_kernel(q, k, v, block_q=128, block_k=128)),
        "derived": "interpret-mode"})
    rows.append({
        "name": "flash_attention_jnp_ref",
        "us": _time(lambda: flash_attention_ref(q, k, v)),
        "derived": "-"})
    return rows
