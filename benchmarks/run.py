"""Benchmark harness entry point — one benchmark per paper table/figure plus
system-level extras. Prints ``name,us_per_call,derived`` CSV rows.

  fig2          accuracy-vs-rounds curves (paper Fig. 2)
  table1        average non-IID accuracy (paper Table I)
  channel_uses  channel-use efficiency (paper §IV claim)
  convergence   Theorem-1 O(1/T) decay + SNR noise floor
  kernels       Pallas kernel micro-benchmarks (interpret mode)
  sim           scenario engine: scan vs loop rounds/sec + MC throughput

Default is a CPU-scaled grid (same protocol, reduced sizes); ``--full``
restores the paper's sizes. ``--only fig2`` etc. selects one benchmark.
The roofline/dry-run analyses are separate (python -m repro.launch.roofline).
"""
from __future__ import annotations

import argparse
import json
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--fast", action="store_true",
                    help="minimal subset for CI smoke")
    ap.add_argument("--bench-out", default="BENCH_kernels.json",
                    help="machine-readable kernel-bench output path "
                         "(fused vs three-pass wall time + modeled HBM "
                         "bytes; tracks the perf trajectory across PRs)")
    ap.add_argument("--sim-out", default="BENCH_sim.json",
                    help="machine-readable sim-bench output path "
                         "(scan vs loop rounds/sec, scan speedup, "
                         "Monte-Carlo throughput)")
    args = ap.parse_args()

    from benchmarks.common import BenchScale
    from repro.obs.manifest import build_manifest
    scale = BenchScale.full() if args.full else BenchScale()
    if args.fast:
        scale = BenchScale(mnist_clients=10, cifar_clients=9,
                           mnist_train=3000, cifar_train=1800, test=800,
                           rounds=10, eval_samples=512)

    rows = []

    def emit(name, us, derived):
        rows.append((name, us, derived))
        print(f"{name},{us:.1f},{derived}", flush=True)

    print("name,us_per_call,derived")
    want = lambda x: args.only in (None, x)

    if want("channel_uses"):
        from benchmarks import channel_uses
        t0 = time.time()
        out = channel_uses.run()
        us = (time.time() - t0) * 1e6 / max(len(out), 1)
        k50 = next(r for r in out if r["K"] == 50 and r["C"] == 3)
        emit("channel_uses_K50_C3", us,
             f"cwfl={k50['cwfl']};dec={k50['decentralized']};"
             f"saving={k50['saving_vs_decentralized']:.0f}x")

    if want("kernels"):
        from benchmarks import kernels_bench
        krows = kernels_bench.run()
        for r in krows:
            emit(r["name"], r["us"], r["derived"])
        payload = {
            r["name"]: {k: v for k, v in r.items() if k != "name"}
            for r in krows}
        # Provenance (repro.obs.manifest): BENCH numbers are attributable
        # to a git sha / device / jax version run-to-run.
        payload["run_manifest"] = build_manifest(
            cfg=vars(args), extra={"bench": "kernels"})
        with open(args.bench_out, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"# wrote {args.bench_out}", flush=True)

    if want("sim"):
        from benchmarks import sim_bench
        srows = sim_bench.run(mc_rounds=3 if args.fast else 8,
                              seeds=2 if args.fast else 4)
        for r in srows:
            emit(r["name"], r["us"], r["derived"])
        payload = {
            r["name"]: {k: v for k, v in r.items() if k != "name"}
            for r in srows}
        # Throughput trail: before overwriting, record this run's
        # steady-state rates relative to the previously committed
        # BENCH_sim.json.  Informational — the prior file came from a
        # different session of a noisy shared box (same-binary re-runs
        # swing +-30-50% here), so regressions should be judged from a
        # same-session A/B (see the registry_indirection_guard entry for
        # the Strategy-API PR's methodology), not from these ratios.
        try:
            with open(args.sim_out) as f:
                prev = json.load(f)
        except (OSError, json.JSONDecodeError):
            prev = {}
        guarded = {"sim_scan": "rounds_per_sec", "sim_mc_vmap": "traj_per_sec",
                   "sim_mc_sharded": "traj_per_sec", "sim_mc_S": "mc_rounds_per_sec"}
        ratios = {}
        for name, row in payload.items():
            metric = next((m for pfx, m in guarded.items()
                           if name.startswith(pfx) and m in row), None)
            if metric and metric in prev.get(name, {}):
                ratios[f"{name}:{metric}"] = round(
                    row[metric] / prev[name][metric], 3)
        if ratios:
            payload["throughput_vs_previous_file"] = {
                "ratios": ratios,
                "min_ratio": min(ratios.values()),
                "note": "cross-session comparison on a shared box; "
                        "informational only",
            }
        for k, v in prev.items():
            if k.endswith("_guard") and k not in payload:
                payload[k] = v      # persist one-off guard records
        payload["run_manifest"] = build_manifest(
            cfg=vars(args), extra={"bench": "sim"})
        with open(args.sim_out, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"# wrote {args.sim_out}", flush=True)

    if want("convergence"):
        from benchmarks import convergence
        t0 = time.time()
        out = convergence.run(T=60 if args.fast else 150)
        us = (time.time() - t0) * 1e6
        for k, v in out.items():
            emit(f"convergence_{k}", us / len(out),
                 f"decay={v['decay_T4_to_T']:.1f}x;floor={v['floor']:.2e}")

    if want("fig2"):
        from benchmarks import fig2_accuracy
        out = fig2_accuracy.run(scale, subset=4 if args.fast else None)
        for r in out:
            emit(f"fig2_{r['dataset']}_{'iid' if r['iid'] else 'noniid'}_"
                 f"{r['label']}",
                 r["seconds_per_round"] * 1e6,
                 f"final={r['final_acc']:.3f};avg={r['avg_acc']:.3f}")

    if want("table1"):
        from benchmarks import table1_accuracy
        out = table1_accuracy.run(
            scale, datasets=("mnist",) if args.fast else ("mnist", "cifar"))
        for ds, cols in out.items():
            for label, acc in cols.items():
                emit(f"table1_{ds}_{label}", 0.0,
                     "-" if acc is None else f"avg={acc:.3f}")


if __name__ == "__main__":
    main()
