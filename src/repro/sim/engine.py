"""The scanned Monte-Carlo round engine (DESIGN.md §Sim).

`run_federated` was a host Python loop: one jitted round, a
``float(loss)`` device→host sync per round, one seed, one static channel.
This engine runs the *whole trajectory* as a single ``lax.scan`` — T
rounds on device, per-round loss/accuracy accumulated in on-device scan
outputs — and is vmap-able over seeds and scenario scalars, so an
8-seed × SNR-grid Monte-Carlo sweep compiles to exactly one jit.

Round body (identical math to the pre-engine loop):

    local:  E epochs of minibatch SGD per client   (vmap over K)
    sync:   strategy aggregation — CWFL routes through the fused
            `repro.kernels.cwfl_round` Pallas fast path via
            ``cwfl.aggregate``'s flatten-once auto-route
    eval:   consensus accuracy on the held-out set (on device)

Scenario hooks (all `lax.scan`-carried, nothing touches the host):

* time-varying channels  → per-round ``Strategy.state_from_view``
  rebuilds (`repro.strategies`) from the `repro.sim.processes` channel
  view;
* client scheduling      → participation masks folded into the round
  coefficients (mask-aware renormalization) on the transmit side, and a
  keep-local-params ``where`` on the receive side;
* cluster churn          → periodic on-device re-clustering
  (``lax.cond``-gated K-means + head election inside the scan body).

Under the ``paper-static`` scenario the engine reproduces the
pre-refactor `run_federated` history bit-for-bit (same key schedule, same
per-round computation; ``mode="loop"`` replays the legacy per-round-jit
structure for A/B benchmarking and the equivalence test).
"""
from __future__ import annotations

import json
import warnings
from pathlib import Path
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import channel as ch
from repro.core.topology import Topology, TopologyConfig
from repro.models.small import accuracy as _accuracy
from repro.obs.telemetry import build_round_telemetry, init_ledger
from repro.optim import sgd
from repro.sim.faults import init_faults, quarantine_mask, step_faults
from repro.sim.processes import (ChannelView, channel_view, csi_perturbation,
                                 init_channel, step_channel)
from repro.sim.scenarios import Scenario
from repro.sim.scheduling import init_schedule, participation_mask
from repro.strategies import get_strategy
from repro.training.federated import FLConfig
from repro.training.local import make_local_runner

# fold_in salt separating the scenario-process key stream (channel, masks,
# CSI, re-clustering) from the paper's training stream — the static path
# consumes exactly the pre-engine keys, bit-for-bit.
_SIM_SALT = 0x51B

# lax.scan unroll for the round loop.  At unroll=1 XLA compiles the while-
# loop body with different elementwise fusion (FMA contraction) than the
# standalone jitted round, which perturbs the precoded strategies
# (cwfl/cotaf: the per_client_mean_sq → amplitude-clip chain) by 1 ulp per
# round; at unroll=2 the loop body fuses identically to the sequential
# jit and the whole trajectory is bit-identical to the legacy per-round
# loop (verified for odd/even T in tests/test_sim_engine.py).
_SCAN_UNROLL = 2


def make_round_local_runner(loss_fn: Callable, cfg: FLConfig, n_k: int):
    """The per-round local-training runner exactly as the engine builds
    it: E epochs of minibatch SGD over a client's ``n_k`` examples.
    Returns ``(optimizer, local_run)``; `repro.sim.sharded` reuses this
    so the sharded trajectory can never drift from the engine's step
    budget or optimizer construction.

    The FedProx µ_p resolves through the strategy (prox variants such as
    ``cwfl_prox`` carry the paper's default; an explicit
    ``cfg.mu_prox > 0`` overrides it) — `repro.training.local.
    fedprox_wrap` then wires the proximal local objective in."""
    strategy = get_strategy(cfg.strategy)
    optimizer = sgd(cfg.lr)
    steps_per_round = max(cfg.local_epochs * (n_k // cfg.batch_size), 1)
    return optimizer, make_local_runner(
        loss_fn, optimizer, cfg.batch_size, steps_per_round,
        strategy.effective_mu_prox(cfg.mu_prox))


def _tree_where(mask: jnp.ndarray, a, b):
    """Per-leaf ``where(mask_k, a_k, b_k)`` over K-stacked pytrees."""
    def pick(x, y):
        m = mask.reshape((mask.shape[0],) + (1,) * (x.ndim - 1))
        return jnp.where(m > 0, x, y)
    return jax.tree.map(pick, a, b)


def _build(init_fn: Callable, apply_fn: Callable, loss_fn: Callable,
           topology: Topology, xs: jnp.ndarray, ys: jnp.ndarray,
           x_test: jnp.ndarray, y_test: jnp.ndarray, cfg: FLConfig,
           scenario: Scenario, topo_cfg: Optional[TopologyConfig],
           telemetry: bool = False, stream=None):
    """Returns ``(prepare, body)``: ``prepare(seed, snr_db)`` builds the
    scan carry + per-round inputs, ``body`` is the round function.  Both
    are pure jnp — jit them together (scan mode, Monte-Carlo vmap) or
    run `prepare` eagerly and jit `body` alone (legacy loop mode).

    ``telemetry`` is a STATIC python flag: when False the carry, scan
    outputs, and every traced op are exactly the untelemetered build —
    the jaxpr is byte-identical, so the goldens replay bitwise.  When
    True the carry grows a cumulative channel-use ledger (``"obs"``) and
    ``body`` emits a third `RoundTelemetry` scan output assembled from
    intermediates the round already computes (`repro.obs.telemetry`).

    ``stream`` (STATIC, requires ``telemetry``) is an optional
    `repro.obs.stream.RoundStream`: the scan inputs grow an absolute
    ``(t, seed, snr)`` tag triple and the body ends with one ORDERED
    `io_callback` draining the round's already-computed metrics +
    telemetry to the host (`repro.obs.stream.stream_tap`) — no new
    arithmetic, so streamed metrics stay bitwise.  Unbatched bodies
    only: Monte-Carlo sweeps must NOT pass ``stream`` here (in-body
    taps break under vmap) — `run_monte_carlo` wraps the trajectory
    with the post-scan `stream_trajectory_tap` instead."""
    strategy = get_strategy(cfg.strategy)
    if stream is not None and not telemetry:
        raise ValueError(
            "stream= drains RoundTelemetry and therefore needs "
            "telemetry=True (the stream IS the telemetry, live)")
    if scenario.strategy is not None and scenario.strategy != strategy.name:
        # The scenario pins a preferred strategy (resolved by CLIs when no
        # explicit choice is given) but FLConfig.strategy always wins in
        # the engine — since the config default is indistinguishable from
        # an explicit choice, the override must at least be loud.
        warnings.warn(
            f"scenario {scenario.name!r} pins strategy "
            f"{scenario.strategy!r} but the run uses cfg.strategy="
            f"{strategy.name!r}; pass FLConfig(strategy="
            f"{scenario.strategy!r}) to honor the scenario's pin",
            UserWarning, stacklevel=3)

    K, n_k = xs.shape[0], xs.shape[1]
    static = scenario.is_static
    dyn_chan = scenario.channel.evolves_geometry  # CSI-only needs no geometry
    masked = not scenario.schedule.is_trivial
    faulty = not scenario.faults.is_trivial       # STATIC flag, like telemetry
    fcfg = scenario.faults
    recluster = scenario.recluster_every
    total_power = float(topology.total_power)
    if dyn_chan and topo_cfg is None:
        raise ValueError(
            "dynamic-channel scenarios need the TopologyConfig that "
            "generated the topology (geometry statics: area, d0, ς, "
            "outage threshold)")

    optimizer, local_run = make_round_local_runner(loss_fn, cfg, n_k)
    x_ev = x_test[: cfg.eval_samples]
    y_ev = y_test[: cfg.eval_samples]

    def prepare(seed, snr_db):
        key = jax.random.PRNGKey(seed)
        k_state, k_init, k_rounds = jax.random.split(key, 3)
        state0 = strategy.init(topology, k_state, cfg, snr_db=snr_db)
        params0 = init_fn(k_init)
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (K,) + x.shape), params0)
        opt_state = jax.vmap(optimizer.init)(stacked)
        round_keys = jax.random.split(k_rounds, cfg.rounds)

        carry = {"stacked": stacked, "opt": opt_state, "consensus": params0}
        if telemetry:
            carry["obs"] = init_ledger()
        scan_xs = {"rkey": round_keys}
        if stream is not None:
            # Absolute round tags for the live tap.  These are scan
            # INPUTS (not carried state) so the checkpoint driver's
            # sliced(lo, hi) hands resumed segments their true absolute
            # round indices and the stream continues seamlessly.
            snr_tag = (jnp.full((), jnp.nan, jnp.float32) if snr_db is None
                       else jnp.asarray(snr_db, jnp.float32))
            scan_xs["stream"] = {
                "t": jnp.arange(cfg.rounds, dtype=jnp.int32),
                "seed": jnp.broadcast_to(jnp.asarray(seed, jnp.int32),
                                         (cfg.rounds,)),
                "snr": jnp.broadcast_to(snr_tag, (cfg.rounds,)),
            }
        if not static:
            scan_xs["skey"] = jax.random.split(
                jax.random.fold_in(key, _SIM_SALT), cfg.rounds)
            scan_xs["t"] = jnp.arange(cfg.rounds)
            nv = (topology.noise_var if snr_db is None
                  else ch.snr_db_to_noise_var(total_power, snr_db))
            if masked:
                carry["sched"] = init_schedule(scenario.schedule, K)
            if faulty:
                carry["faults"] = init_faults(fcfg, K)
            if dyn_chan:
                carry["chan"] = init_channel(
                    topology, topo_cfg, jax.random.fold_in(key, _SIM_SALT + 1))
            if strategy.reclusters and recluster > 0:
                carry["plan"] = state0.plan
            state0 = (state0, jnp.asarray(nv, jnp.float32))
        return state0, carry, scan_xs

    def make_body(ctx):
        """Bind the per-trajectory context (strategy state; + noise var in
        dynamic mode) into the round body as a CLOSURE, exactly like the
        legacy ``round_fn``'s jit closure — with eager `prepare` the
        static-scenario round compiles with the state embedded as
        constants, which keeps the history bit-identical to the
        pre-engine loop (argument-vs-constant changes XLA fusion by ulps).
        """
        if static:
            state0, nv = ctx, None
        else:
            state0, nv = ctx

        def dynamic_sync(carry, stacked, inp, k_agg):
            """One scenario-aware sync: channel step → fault step →
            state rebuild → masked aggregation.  Mutates ``carry`` (a
            per-round copy).  Returns ``(new, consensus, state, mask,
            reclustered, fault_extras)`` — the trailing four feed the
            telemetry hook and are plain Python ``None``s (no extra
            traced ops) when unused."""
            t = inp["t"]
            if faulty:
                (k_chan, k_csi, k_mask, k_cluster, k_fault,
                 k_handoff) = jax.random.split(inp["skey"], 6)
            else:
                k_chan, k_csi, k_mask, k_cluster = jax.random.split(
                    inp["skey"], 4)

            if dyn_chan:
                chan = step_channel(carry["chan"], scenario.channel, topo_cfg,
                                    k_chan)
                carry["chan"] = chan
                view = channel_view(chan, topo_cfg)
            else:
                view = ChannelView(link_gain=topology.link_gain,
                                   link_snr=topology.link_snr,
                                   adjacency=topology.adjacency)

            mask = None
            if masked:
                mask, carry["sched"] = participation_mask(
                    scenario.schedule, carry["sched"], t, k_mask, K)

            alive = None
            fault_extras = None
            if faulty:
                # Fault plane (repro.sim.faults): advance the crash /
                # burst / blackout chains, fold transmit outages into the
                # participation mask (same renormalization path as
                # scheduling absences), and quarantine poisoned client
                # updates BEFORE they can touch a MAC matmul — a
                # quarantined client transmits nothing and keeps its own
                # pre-round params (0 × NaN = NaN, so masking alone
                # cannot contain a non-finite update).
                carry["faults"], fview = step_faults(carry["faults"], fcfg,
                                                     k_fault)
                alive = fview.alive
                mask = (fview.tx_ok if mask is None
                        else mask * fview.tx_ok)
                q = None
                if fcfg.divergence_guard:
                    q = quarantine_mask(stacked, fcfg.quarantine_norm)
                    stacked = _tree_where(q, stacked, carry["stacked"])
                    mask = mask * q
                if telemetry:
                    fault_extras = {
                        "alive": alive,
                        "tx_ok": fview.tx_ok,
                        "burst": fview.burst,
                        "deep_fade": fview.deep_fade,
                        "quarantined": (jnp.zeros((), jnp.float32)
                                        if q is None else jnp.sum(1.0 - q)),
                    }
            # Imperfect CSI hits every strategy that water-fills power
            # from channel estimates (CWFL member→head, COTAF →server).
            csi = (csi_perturbation(k_csi, K, scenario.channel.csi_error_std)
                   if (strategy.water_fills
                       and scenario.channel.csi_error_std > 0) else None)

            plan = None
            reclustered = None
            if strategy.reclusters and recluster > 0:
                fire = (t % recluster) == 0
                plan = jax.lax.cond(
                    fire,
                    lambda: strategy.recluster(view, cfg.num_clusters,
                                               k_cluster),
                    lambda: carry["plan"])
                carry["plan"] = plan
                if telemetry:
                    reclustered = fire

            if faulty:
                # Infrastructure handoff (stateless — derived fresh each
                # round, so a recovered head/server resumes on its own):
                # CWFL re-elects dead cluster-heads; strategies without a
                # plan pass through.  The re-elected plan deliberately
                # does NOT go back into carry["plan"].
                plan = strategy.on_head_failure(state0, plan, view, alive,
                                                k_handoff)

            state = strategy.state_from_view(state0, view, nv, csi=csi,
                                             mask=mask, plan=plan,
                                             alive=alive)
            new, consensus = strategy.aggregate(stacked, state, k_agg,
                                                mask=mask, alive=alive)

            recv = (strategy.receive_mask(state, mask, alive=alive)
                    if mask is not None else None)
            if recv is not None:
                # Receive side: absent clients keep their locally-trained
                # params (no downlink for a client out of the round) while
                # forced-present receivers (heads/server) keep the
                # aggregate they hold; if NOBODY participated the sync is
                # skipped and the previous consensus stands (also swallows
                # fedavg's 0/0 weights).  A ``None`` recv means the
                # aggregate already encodes absences (decentralized's
                # pruned graph) — no fold at all.
                present = jnp.sum(mask) > 0
                new = _tree_where(recv * present, new, stacked)
                consensus = jax.tree.map(
                    lambda n, o: jnp.where(present, n, o),
                    consensus, carry["consensus"])
            return new, consensus, state, mask, reclustered, fault_extras

        def body(carry, inp):
            carry = dict(carry)
            k_local, k_agg = jax.random.split(inp["rkey"])
            client_keys = jax.random.split(k_local, K)
            trained, opt_state, losses = jax.vmap(local_run)(
                carry["stacked"], carry["opt"], xs, ys, client_keys)
            if static:
                stacked, consensus = strategy.aggregate(trained, state0,
                                                        k_agg)
                state, mask, reclustered, fault_extras = (state0, None,
                                                          None, None)
            else:
                (stacked, consensus, state, mask, reclustered,
                 fault_extras) = dynamic_sync(carry, trained, inp, k_agg)
            logits = apply_fn(consensus, x_ev)
            acc = _accuracy(logits, y_ev)
            carry.update(stacked=stacked, opt=opt_state, consensus=consensus)
            if not telemetry:
                return carry, (jnp.mean(losses), acc)
            # Telemetry losses are a FRESH full-shard forward pass on the
            # locally-trained params — NOT the minibatch `losses` above.
            # Any reduction over `losses` other than the round's own
            # jnp.mean (which CSEs with it) gives the buffer a second
            # consumer, un-fuses the mean from the training loop, and
            # perturbs the reported train_loss by ulps; `trained` is
            # already materialized (it feeds the sync), so reading it is
            # bit-neutral.  Full-batch per-client loss is also the better
            # observable: deterministic, minibatch-noise-free.
            tele_losses = jax.vmap(loss_fn)(trained, xs, ys)
            tele, carry["obs"] = build_round_telemetry(
                strategy, state, losses=tele_losses, stacked=trained,
                new_stacked=stacked, consensus=consensus, mask=mask,
                num_clients=K, num_clusters=cfg.num_clusters,
                ledger=carry["obs"], reclustered=reclustered,
                fault_extras=fault_extras)
            train_loss = jnp.mean(losses)
            if stream is not None:
                # Live tap: operands are the values this round already
                # computed — the tap adds an effect, never an equation
                # (stream-on metrics stay bitwise; pinned by
                # tests/test_stream.py).
                from repro.obs.stream import stream_tap
                stream_tap(stream, t=inp["stream"]["t"],
                           seed=inp["stream"]["seed"],
                           snr=inp["stream"]["snr"], loss=train_loss,
                           acc=acc, telemetry=tele, ordered=True)
            return carry, (train_loss, acc, tele)

        return body

    return prepare, make_body


def checkpoint_manifest(directory, cfg, scenario, strategy_name: str,
                        resume: bool) -> None:
    """Stamp (or validate) the checkpoint directory's run identity.

    First save writes an `repro.obs.manifest` record whose
    ``config_hash`` covers (config, scenario, strategy); every later
    save/resume against the same directory must hash identically —
    resuming a trajectory under a different protocol would silently
    splice incompatible histories, so it is an error instead.
    """
    from repro.obs.manifest import build_manifest, config_hash, to_jsonable

    directory = Path(directory)
    chash = config_hash(to_jsonable(cfg), to_jsonable(scenario),
                        strategy_name)
    path = directory / "manifest.json"
    if path.exists():
        recorded = json.loads(path.read_text()).get("config_hash")
        if recorded != chash:
            raise ValueError(
                f"checkpoint directory {directory} belongs to a different "
                f"run protocol (manifest config_hash {recorded!r} != this "
                f"run's {chash!r}); use a fresh checkpoint dir or the "
                f"original config/scenario/strategy")
    elif resume:
        raise FileNotFoundError(
            f"resume: {path} not found — nothing to resume from")
    else:
        directory.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(
            build_manifest(cfg, scenario, strategy_name,
                           extra={"kind": "trajectory-checkpoint"}),
            indent=2, sort_keys=True))


def _run_scan_checkpointed(fn, carry, scan_xs, T: int, directory,
                           every: int, *, resume: bool,
                           resume_step: Optional[int], stop_after:
                           Optional[int], cfg, scenario, strategy_name: str,
                           stream=None):
    """Drive the scanned trajectory in checkpointed segments.

    The T-round scan is split at every ``every`` rounds; after each
    segment the FULL carry (param stacks, optimizer + strategy/process
    states, telemetry ledger) and the metrics accumulated so far are
    persisted via `repro.checkpoint` under ``step_<rounds_done>``.
    Because the scanned trajectory is bit-identical to the per-round
    loop over the same body (the unroll-fusion contract pinned in
    tests/test_sim_engine.py), a chunked scan — and therefore an
    interrupted-and-resumed trajectory — replays the uninterrupted
    history BITWISE; `prepare` is eager and deterministic, so the
    per-round scan inputs regenerate identically on resume and only the
    carry needs disk.

    Returns ``(carry, out, rounds_done)``; ``rounds_done < T`` only when
    ``stop_after`` deliberately kills the run at a segment boundary (the
    CI chaos-smoke's crash stand-in) or an attached ``stream``'s monitor
    escalated an alert to an abort (`repro.obs.monitor`) — in both cases
    the segment's checkpoint is already on disk, so the run resumes
    exactly where it stopped (checkpoint-then-stop).
    """
    from repro.checkpoint import (latest_step, load_checkpoint,
                                  save_checkpoint)

    directory = Path(directory)
    every = T if every is None or int(every) <= 0 else min(int(every), T)
    checkpoint_manifest(directory, cfg, scenario, strategy_name, resume)

    def sliced(lo, hi):
        return jax.tree.map(lambda x: x[lo:hi], scan_xs)

    def out_template(n):
        shapes = jax.eval_shape(fn, carry, sliced(0, n))[1]
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)

    start, acc = 0, None
    if resume:
        step = resume_step if resume_step is not None else (
            latest_step(directory))
        if step is None:
            raise FileNotFoundError(
                f"resume: no checkpoint steps in {directory}")
        if not 0 < step <= T:
            raise ValueError(
                f"resume: checkpoint step {step} outside this run's "
                f"1..{T} round range")
        payload = load_checkpoint(
            directory, {"carry": carry, "out": out_template(step)},
            step=step)
        carry, acc, start = payload["carry"], payload["out"], int(step)

    pos = start
    while pos < T:
        end = min(pos + every, T)
        carry, seg = fn(carry, sliced(pos, end))
        acc = seg if acc is None else jax.tree.map(
            lambda a, b: jnp.concatenate([a, b], axis=0), acc, seg)
        pos = end
        save_checkpoint(directory, pos, {"carry": carry, "out": acc})
        if stop_after is not None and pos >= int(stop_after) and pos < T:
            break
        if stream is not None:
            # Callbacks dispatch asynchronously; drain the segment's
            # records before polling the monitor's escalation decision.
            jax.effects_barrier()
        if stream is not None and stream.should_abort and pos < T:
            # Alert escalation: the ordered tap has already drained this
            # segment's rounds, the checkpoint above has the full carry —
            # stop here, resumable.
            break
    return carry, acc, pos


def make_trajectory_fn(prepare: Callable, make_body: Callable) -> Callable:
    """The per-trajectory closure: ``traj(seed, snr_db) -> (loss, acc)``,
    both ``(T,)`` — plus a round-stacked `RoundTelemetry` third element on
    telemetry-enabled builds.  This is the ONE traced body every
    Monte-Carlo executor consumes — `run_monte_carlo`'s single-device
    ``vmap`` grid and the device-parallel ``shard_map`` grid in
    :mod:`repro.sim.sharded` batch the same function, so the two paths can
    only differ by how XLA batches it (see the parity notes in DESIGN.md
    §Sharded-MC)."""
    def traj(seed, snr_db):
        ctx, carry0, scan_xs = prepare(seed, snr_db)
        _, out = jax.lax.scan(make_body(ctx), carry0, scan_xs,
                              unroll=_SCAN_UNROLL)
        return out
    return traj


def run_rounds(init_fn: Callable, apply_fn: Callable, loss_fn: Callable,
               topology: Topology, xs: jnp.ndarray, ys: jnp.ndarray,
               x_test: jnp.ndarray, y_test: jnp.ndarray, cfg: FLConfig,
               scenario: Optional[Scenario] = None,
               topo_cfg: Optional[TopologyConfig] = None,
               mode: str = "scan",
               progress: Optional[Callable] = None,
               shard: Optional[str] = None,
               mesh=None,
               telemetry: bool = False,
               timers=None,
               checkpoint_dir: Optional[str] = None,
               checkpoint_every: int = 0,
               resume: bool = False,
               resume_step: Optional[int] = None,
               stop_after: Optional[int] = None,
               stream=None) -> dict[str, Any]:
    """Run one FL trajectory; returns history with on-device arrays.

    ``mode="scan"`` (default): the whole trajectory is one jit — no
    per-round host sync; metrics come back as (T,) arrays.
    ``mode="loop"``: the legacy per-round-jit host loop (bit-identical
    history; supports a live per-round ``progress(r, loss, acc)``
    callback, and is the baseline the scan speedup is measured against).
    ``shard="clients"``: distribute the stacked K-client axis over a
    ``("clients",)`` mesh (`repro.sim.sharded.run_rounds_client_sharded`
    — local training per rank, the per-cluster OTA sums riding a mesh
    collective); static CWFL scenarios only.
    ``telemetry=True`` (static flag, `repro.obs`): record a per-round
    `RoundTelemetry` under ``history["telemetry"]`` — with the flag off
    the traced computation is byte-identical to pre-obs builds.
    ``timers``: an optional `repro.obs.profiling.PhaseTimers` splitting
    the run into ``trace_compile`` (AOT ``lower().compile()``) and
    ``execute`` (to ``block_until_ready``) wall phases; ``None`` keeps
    the default jit path untouched.

    Checkpoint/resume (DESIGN.md §Faults): ``checkpoint_dir`` persists
    the full scan carry + accumulated metrics every
    ``checkpoint_every`` rounds (0 ⇒ one final checkpoint) via
    `repro.checkpoint`, manifest-stamped with the run's config hash;
    ``resume=True`` restores the latest step (or ``resume_step``) and
    continues such that the interrupted+resumed history is BITWISE
    identical to an uninterrupted run.  ``stop_after=r`` deliberately
    exits at the first segment boundary ≥ r (crash simulation — CI's
    chaos-smoke).  Scan mode only; ``mode="loop"`` raises.

    ``stream`` (STATIC, needs ``telemetry=True``): a
    `repro.obs.stream.RoundStream` drained live from inside the scan via
    an ordered `io_callback` — records arrive on the host in round order
    while the trajectory runs, metrics stay bitwise, and with
    ``stream=None`` the traced jaxpr is byte-identical to a
    streaming-unaware build.  A stream whose monitor escalates alerts to
    aborts requires ``checkpoint_dir`` (the abort IS a
    checkpoint-then-stop); scan mode only.
    """
    scenario = scenario or Scenario()
    if checkpoint_dir is None and (resume or stop_after is not None):
        raise ValueError(
            "resume/stop_after need checkpoint_dir — there is nothing to "
            "restore from or checkpoint into")
    if stream is not None:
        if not telemetry:
            raise ValueError(
                "stream= drains RoundTelemetry live and needs "
                "telemetry=True")
        if mode != "scan":
            raise ValueError(
                "stream= taps the scanned trajectory; mode='loop' already "
                "has a live per-round progress callback")
        if stream.escalates and checkpoint_dir is None:
            raise ValueError(
                "abort-on-alert escalates via the checkpoint machinery "
                "(checkpoint-then-stop, resumable); pass checkpoint_dir")
    if checkpoint_dir is not None:
        if mode != "scan":
            raise ValueError(
                "checkpointing chunks the scanned trajectory; "
                "mode='loop' is not supported (and needs no resume — it "
                "is already a host loop)")
        if timers is not None:
            raise ValueError(
                "timers profile a single-segment run; combine them with "
                "checkpointing and the phases stop meaning anything")
    if shard is not None:
        if shard != "clients":
            raise ValueError(
                f"run_rounds shards the client axis only (shard='clients'); "
                f"got {shard!r} — trajectory sharding (shard='mc') lives in "
                "run_monte_carlo")
        if mode != "scan" or progress is not None:
            raise ValueError(
                "shard='clients' runs the scanned trajectory only — "
                "mode='loop' / live progress callbacks are not supported "
                "on the sharded path")
        from repro.sim import sharded
        return sharded.run_rounds_client_sharded(
            init_fn, apply_fn, loss_fn, topology, xs, ys, x_test, y_test,
            cfg, scenario=scenario, mesh=mesh, telemetry=telemetry,
            checkpoint_dir=checkpoint_dir, checkpoint_every=checkpoint_every,
            resume=resume, resume_step=resume_step, stop_after=stop_after,
            stream=stream)
    prepare, make_body = _build(init_fn, apply_fn, loss_fn, topology, xs, ys,
                                x_test, y_test, cfg, scenario, topo_cfg,
                                telemetry=telemetry, stream=stream)
    T = cfg.rounds

    # `prepare` runs EAGERLY in both modes — the same eager/jit boundary the
    # legacy loop had (offline setup + init op-by-op, rounds compiled), so
    # the scanned trajectory stays bit-identical to it; only Monte-Carlo
    # sweeps trace `prepare` (under vmap over seeds/scenario scalars).
    ctx, carry, scan_xs = prepare(cfg.seed, cfg.snr_db)
    body = make_body(ctx)

    tele = None
    if mode == "scan":
        fn = jax.jit(
            lambda c, x: jax.lax.scan(body, c, x, unroll=_SCAN_UNROLL))
        if checkpoint_dir is not None:
            carry, out, _ = _run_scan_checkpointed(
                fn, carry, scan_xs, T, checkpoint_dir, checkpoint_every,
                resume=resume, resume_step=resume_step,
                stop_after=stop_after, cfg=cfg, scenario=scenario,
                strategy_name=get_strategy(cfg.strategy).name,
                stream=stream)
        elif timers is not None:
            with timers.phase("trace_compile"):
                fn = fn.lower(carry, scan_xs).compile()
            with timers.phase("execute"):
                carry, out = jax.block_until_ready(fn(carry, scan_xs))
        else:
            carry, out = fn(carry, scan_xs)
        if stream is not None:
            # The tap's callbacks are asynchronous; make sure every round
            # reached the host before the caller inspects the stream.
            jax.block_until_ready(out)
            jax.effects_barrier()
        if telemetry:
            loss, acc, tele = out
        else:
            loss, acc = out
        consensus = carry["consensus"]
    elif mode == "loop":
        body_j = jax.jit(body)
        loss_l, acc_l, tele_l = [], [], []
        for t in range(T):
            inp = jax.tree.map(lambda x: x[t], scan_xs)
            if timers is not None:
                with timers.phase("execute"):
                    carry, out = jax.block_until_ready(body_j(carry, inp))
            else:
                carry, out = body_j(carry, inp)
            if telemetry:
                l, a, tl = out
                tele_l.append(tl)
            else:
                l, a = out
            loss_l.append(l)
            acc_l.append(a)
            if progress is not None:
                progress(t + 1, float(l), float(a))
        consensus = carry["consensus"]
        loss, acc = jnp.stack(loss_l), jnp.stack(acc_l)
        if telemetry:
            tele = jax.tree.map(lambda *x: jnp.stack(x), *tele_l)
    else:
        raise ValueError(f"mode must be 'scan' or 'loop', got {mode!r}")

    history = {
        # rounds actually run: == T except when stop_after killed the
        # checkpointed run at a segment boundary (crash simulation).
        "round": np.arange(1, int(loss.shape[0]) + 1),
        "train_loss": loss,
        "test_acc": acc,
        "final_params": consensus,
        "avg_acc": jnp.mean(acc),
        "final_acc": acc[-1],
    }
    if telemetry:
        history["telemetry"] = tele
    return history


def run_monte_carlo(init_fn: Callable, apply_fn: Callable, loss_fn: Callable,
                    topology: Topology, xs: jnp.ndarray, ys: jnp.ndarray,
                    x_test: jnp.ndarray, y_test: jnp.ndarray, cfg: FLConfig,
                    scenario: Optional[Scenario] = None,
                    topo_cfg: Optional[TopologyConfig] = None,
                    seeds: int = 8,
                    snr_grid=None,
                    shard: Optional[str] = None,
                    mesh=None,
                    telemetry: bool = False,
                    timers=None,
                    stream=None) -> dict[str, Any]:
    """Monte-Carlo grid: ``seeds`` × ``snr_grid`` full trajectories in ONE
    jit (vmap over the seed axis, vmap over the scenario-scalar axis,
    `lax.scan` over rounds inside).

    ``snr_grid`` defaults to ``scenario.snr_grid`` when the scenario
    defines one (e.g. ``snr-sweep``); ``None``/empty sweeps only seeds.
    ``shard="mc"`` distributes the flattened seeds × SNR trajectory grid
    over the device mesh via ``shard_map`` (`repro.sim.sharded`) instead
    of batching it all onto one device; the metrics are identical (see
    the parity contract pinned by ``tests/test_sim_sharded.py``).
    Returns ``train_loss``/``test_acc`` of shape (S, T) or (S, G, T);
    with ``telemetry=True`` a trajectory-batched `RoundTelemetry` rides
    under ``history["telemetry"]`` (leading axes (S,[G,]T)).  ``timers``:
    optional `PhaseTimers` — see `run_rounds`.

    ``stream`` (STATIC, needs ``telemetry=True``): per-round records for
    every trajectory in the sweep.  The trajectory is vmapped, so the
    tap sits AFTER each trajectory's scan (`stream_trajectory_tap` on
    the round-stacked outputs — in-body taps either cannot batch
    (ordered) or re-fuse the vmapped loss reduction by a ulp
    (unordered); the post-scan tap reads materialized buffers and keeps
    the sweep bitwise) and the callback is unordered — consumers key on
    the explicit ``(seed, snr_db, round)`` tags, never arrival order.
    Under ``shard="mc"`` the stream is scoped to rank 0's trajectory
    chunk (rank-0 emit; see `repro.sim.sharded`).
    """
    scenario = scenario or Scenario()
    if snr_grid is None and scenario.snr_grid:
        snr_grid = scenario.snr_grid
    if stream is not None and not telemetry:
        raise ValueError(
            "stream= drains RoundTelemetry live and needs telemetry=True")
    prepare, make_body = _build(init_fn, apply_fn, loss_fn, topology, xs, ys,
                                x_test, y_test, cfg, scenario, topo_cfg,
                                telemetry=telemetry)
    traj = make_trajectory_fn(prepare, make_body)
    if stream is not None:
        from repro.obs.stream import stream_trajectory_tap
        base_traj = traj

        def traj(seed, snr_db):
            loss, acc, tele = base_traj(seed, snr_db)
            stream_trajectory_tap(stream, seed=seed, snr=snr_db, loss=loss,
                                  acc=acc, telemetry=tele)
            return loss, acc, tele

    def _run(fn, *a):
        fn = jax.jit(fn)
        if timers is None:
            return fn(*a)
        with timers.phase("trace_compile"):
            fn = fn.lower(*a).compile()
        with timers.phase("execute"):
            return jax.block_until_ready(fn(*a))

    seed_arr = jnp.asarray(cfg.seed + np.arange(seeds))
    tele = None
    if shard is not None:
        if shard != "mc":
            raise ValueError(
                f"run_monte_carlo shards the trajectory grid only "
                f"(shard='mc'); got {shard!r} — client-axis sharding "
                "(shard='clients') lives in run_rounds")
        from repro.sim import sharded
        out = sharded.monte_carlo_sharded(
            traj, seed_arr, snr_grid, cfg.snr_db, cfg.rounds, mesh=mesh,
            telemetry=telemetry, stream=stream)
        if telemetry:
            loss, acc, grid, tele = out
        else:
            loss, acc, grid = out
    elif snr_grid is None:
        out = _run(jax.vmap(traj, in_axes=(0, None)), seed_arr, cfg.snr_db)
        grid = None
        if telemetry:
            loss, acc, tele = out
        else:
            loss, acc = out
    else:
        grid = jnp.asarray(snr_grid, jnp.float32)
        out = _run(jax.vmap(jax.vmap(traj, in_axes=(None, 0)),
                            in_axes=(0, None)), seed_arr, grid)
        if telemetry:
            loss, acc, tele = out
        else:
            loss, acc = out
    if stream is not None:
        jax.block_until_ready(loss)
        jax.effects_barrier()
    history = {
        "train_loss": loss,
        "test_acc": acc,
        "final_acc": acc[..., -1],
        "seeds": seed_arr,
        "snr_grid": grid,
    }
    if telemetry:
        history["telemetry"] = tele
    return history
