"""Scan-legal fault processes: crashes, dropout bursts, blackouts (§Faults).

The paper's stated premise is that "a powerful server may not be
available for parameter aggregation due to increased latency and server
failures" — yet a reproduction with immortal cluster-heads never tests
it.  This module makes node failure a *process* indexed by the round t,
in the mold of `repro.sim.processes`: pure-jnp state transitions riding
the engine's ``lax.scan`` carry, realized each round into a
:class:`FaultView` the engine folds into the participation mask and the
strategy recovery hooks (`Strategy.on_head_failure`).

Three mechanisms compose (DESIGN.md §Faults):

* **Markov crash/recovery chains** — each node is an independent 2-state
  (up/down) Markov chain:

      P(up → down) = p_crash,   P(down → up) = p_recover.

  A *down* node neither transmits nor receives; when the down node is a
  cluster-head (or the COTAF server) the strategy's
  ``on_head_failure`` hook re-elects a surviving replacement.

* **Correlated dropout bursts** — a global 2-state burst chain
  (enter w.p. ``burst_prob``, exit w.p. ``burst_recover_prob``); while a
  burst is active each client is silenced i.i.d. w.p. ``burst_frac``.
  Unlike the per-client i.i.d. dropout of `repro.sim.scheduling`, the
  shared burst state correlates outages across clients and across rounds
  (interference storms, backhaul congestion).

* **Deep-fade blackouts** — w.p. ``deep_fade_prob`` a round starts a
  blackout of ``deep_fade_rounds`` rounds during which NO client can
  transmit; the engine's all-masked guard then freezes the consensus
  (the round is skipped, exactly the physical behaviour of a fully
  faded MAC).

The **divergence guard** (``divergence_guard`` / ``quarantine_norm``) is
not a channel process but a receiver-side defense the engine applies to
the post-local-training parameter stacks: clients whose update is
non-finite or whose per-channel-use power ‖θ‖²/d exceeds
``quarantine_norm`` are *quarantined* — their transmit-mask entry is
zeroed (so the mask-aware renormalization excludes them, same path as
scheduling absences) and their poisoned parameters are replaced by their
own pre-round params.  The replacement matters: a masked client still
contributes ``0 × θ_k`` terms to the OTA matmuls, and ``0 × NaN = NaN``
— masking alone cannot stop a poisoned transmit from NaN-ing the
consensus (:func:`quarantine_mask` + the engine's ``_tree_where`` fold).

Everything is a NamedTuple pytree / pure jnp so it scans and vmaps; a
config with :attr:`FaultConfig.is_trivial` adds ZERO traced ops to the
engine (static-flag discipline — same contract as telemetry).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Knobs of the round-indexed fault process (all off ⇒ no faults)."""

    crash_prob: float = 0.0          # P(up → down) per node per round
    recover_prob: float = 0.0        # P(down → up) per node per round
    burst_prob: float = 0.0          # P(calm → burst) per round
    burst_recover_prob: float = 0.0  # P(burst → calm) per round
    burst_frac: float = 0.0          # P(client silenced | burst active)
    deep_fade_prob: float = 0.0      # P(blackout starts) per round
    deep_fade_rounds: int = 1        # blackout length (rounds)
    divergence_guard: bool = False   # quarantine poisoned client updates
    quarantine_norm: float = 0.0     # ‖θ‖²/d quarantine threshold (0 = only
                                     # non-finite updates are quarantined)

    @property
    def is_trivial(self) -> bool:
        """True when every mechanism is off ⇒ the engine skips fault
        plumbing entirely (byte-identical jaxpr to a faultless build)."""
        return (self.crash_prob <= 0.0 and self.burst_prob <= 0.0
                and self.deep_fade_prob <= 0.0
                and not self.divergence_guard)


class FaultState(NamedTuple):
    """Scan-carried state of the fault process."""

    node_up: jnp.ndarray    # (K,) float {0,1}: Markov up/down per node
    burst: jnp.ndarray      # () float {0,1}: dropout burst active
    fade_left: jnp.ndarray  # () float: blackout rounds remaining


class FaultView(NamedTuple):
    """One round's realized faults — what the engine folds in."""

    alive: jnp.ndarray      # (K,) {0,1} node up (crashed nodes are 0)
    tx_ok: jnp.ndarray      # (K,) {0,1} can transmit: alive ∧ ¬burst ∧ ¬fade
    burst: jnp.ndarray      # () {0,1} dropout burst active this round
    deep_fade: jnp.ndarray  # () {0,1} blackout active this round


def init_faults(cfg: FaultConfig, num_clients: int) -> FaultState:
    """Everyone up, no burst, no blackout at round 0."""
    del cfg
    return FaultState(node_up=jnp.ones((num_clients,), jnp.float32),
                      burst=jnp.zeros((), jnp.float32),
                      fade_left=jnp.zeros((), jnp.float32))


def step_faults(state: FaultState, cfg: FaultConfig,
                key: jax.Array) -> Tuple[FaultState, FaultView]:
    """Advance every fault chain one round (pure; scan-body safe)."""
    K = state.node_up.shape[0]
    k_crash, k_recover, k_enter, k_exit, k_hit, k_fade = jax.random.split(
        key, 6)

    # Per-node 2-state Markov chain.
    crash = jax.random.bernoulli(k_crash, cfg.crash_prob, (K,))
    recover = jax.random.bernoulli(k_recover, cfg.recover_prob, (K,))
    up = jnp.where(state.node_up > 0,
                   jnp.where(crash, 0.0, 1.0),
                   jnp.where(recover, 1.0, 0.0))

    # Global burst chain + i.i.d. per-client hits while active.
    enter = jax.random.bernoulli(k_enter, cfg.burst_prob)
    leave = jax.random.bernoulli(k_exit, cfg.burst_recover_prob)
    burst = jnp.where(state.burst > 0,
                      jnp.where(leave, 0.0, 1.0),
                      jnp.where(enter, 1.0, 0.0))
    hit = jax.random.bernoulli(k_hit, cfg.burst_frac, (K,)).astype(
        jnp.float32)
    burst_ok = 1.0 - burst * hit

    # Deep-fade blackout: a countdown; a new blackout can only start once
    # the previous one has fully drained.
    fade_left = jnp.maximum(state.fade_left - 1.0, 0.0)
    start = jax.random.bernoulli(k_fade, cfg.deep_fade_prob) & (
        fade_left <= 0.0)
    fade_left = jnp.where(start, float(cfg.deep_fade_rounds), fade_left)
    fading = (fade_left > 0.0).astype(jnp.float32)

    tx_ok = up * burst_ok * (1.0 - fading)
    new_state = FaultState(node_up=up, burst=burst, fade_left=fade_left)
    view = FaultView(alive=up, tx_ok=tx_ok, burst=burst, deep_fade=fading)
    return new_state, view


def quarantine_mask(stacked, limit: float = 0.0) -> jnp.ndarray:
    """(K,) {0,1} health flag per client of a K-stacked pytree: 1 iff the
    client's update is entirely finite and (when ``limit > 0``) its
    per-channel-use power ‖θ_k‖²/d stays under ``limit``.

    The power criterion reuses eq. (5)'s own estimator
    (`cwfl.per_client_mean_sq`) so "exploding" means exploding *in the
    quantity the precoder would try to transmit*.  Division of an inf
    norm by d yields inf, and any NaN leaf propagates NaN — both compare
    unhealthy, so the finite check alone already catches them; the
    explicit ``isfinite`` reduction keeps the flag well-defined even at
    ``limit = 0``.
    """
    from repro.core.cwfl import per_client_mean_sq

    leaves = jax.tree.leaves(stacked)
    rows = leaves[0].shape[0]
    finite = jnp.ones((rows,), bool)
    for x in leaves:
        finite &= jnp.all(jnp.isfinite(x.astype(jnp.float32)
                                       .reshape(rows, -1)), axis=1)
    ok = finite
    if limit > 0.0:
        ok &= per_client_mean_sq(stacked) <= limit
    return ok.astype(jnp.float32)
