"""repro.sim — the scenario-simulation subsystem (DESIGN.md §Sim).

Layers a *dynamic* wireless world on top of the paper's stationary model
(`repro.core.topology`): time-varying channel processes, per-round client
scheduling, and a fully-scanned Monte-Carlo round engine that runs entire
FL trajectories on device (vmap-able over seeds and scenario scalars).
"""
from repro.sim.processes import (ChannelProcessConfig, ChannelState,
                                 ChannelView, channel_view, csi_perturbation,
                                 init_channel, step_channel)
from repro.sim.scheduling import (ScheduleConfig, ScheduleState,
                                  init_schedule, participation_mask)
from repro.sim.scenarios import SCENARIOS, Scenario, get_scenario
from repro.sim.engine import run_monte_carlo, run_rounds
