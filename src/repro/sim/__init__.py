"""repro.sim — the scenario-simulation subsystem (DESIGN.md §Sim).

Layers a *dynamic* wireless world on top of the paper's stationary model
(`repro.core.topology`): time-varying channel processes, per-round client
scheduling, and a fully-scanned Monte-Carlo round engine that runs entire
FL trajectories on device (vmap-able over seeds and scenario scalars).
`repro.sim.sharded` distributes the same trajectories across the device
mesh — the seeds × SNR grid over a ``("mc",)`` axis, or one large-K
trajectory's client axis over ``("clients",)`` (DESIGN.md §Sharded-MC).
"""
from repro.sim.faults import (FaultConfig, FaultState, FaultView,
                              init_faults, quarantine_mask, step_faults)
from repro.sim.processes import (ChannelProcessConfig, ChannelState,
                                 ChannelView, channel_view, csi_perturbation,
                                 init_channel, step_channel)
from repro.sim.scheduling import (ScheduleConfig, ScheduleState,
                                  init_schedule, participation_mask)
from repro.sim.scenarios import SCENARIOS, Scenario, get_scenario
from repro.sim.engine import make_trajectory_fn, run_monte_carlo, run_rounds
from repro.sim.sharded import (monte_carlo_sharded,
                               run_rounds_client_sharded)
