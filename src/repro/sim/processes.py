"""Time-varying channel processes layered on `repro.core.topology` (§Sim).

The paper evaluates under a single stationary topology ("channel constant
across rounds").  This module makes every ingredient of that topology a
*process* indexed by the round t, so the scanned engine can re-derive the
per-round channel view entirely on device:

* **Block Rayleigh fading, Gauss-Markov correlated** (the standard
  first-order model, cf. arXiv 2207.09232):
      h̃_{t+1} = ρ h̃_t + sqrt(1 − ρ²) w_t,   w_t ~ CN(0, 1) symmetric,
  so E|h̃_t|² = 1 for all t and ρ = 1 recovers the paper's static channel
  bit-for-bit (the innovation term is multiplied by exactly 0.0).

* **Log-normal shadowing**, AR(1) in dB:
      s_{t+1} = ρ_sh s_t + sqrt(1 − ρ_sh²) n_t,  n_t ~ N(0, σ_sh²) (dB),
  entering the amplitude as 10^{s/20} (symmetric across each link).

* **Random-waypoint mobility**: each client moves toward its waypoint at
  ``speed`` m/round; on arrival it draws a fresh waypoint uniformly in the
  deployment area.  Positions re-derive pathloss, link SNR and the
  outage-pruned adjacency every round — exactly `make_topology`'s rules.

* **Imperfect CSI**: a mean-one log-normal perturbation of the effective
  water-filling gains (`csi_perturbation`) — the power allocator sees a
  noisy channel estimate while the *true* channel still carries the
  signal (`cwfl.state_from_plan(csi_perturb=...)`).

State lives in a NamedTuple (a pytree) so it rides the engine's
``lax.scan`` carry; all steps are pure jnp and vmap-able over seeds.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.topology import (Topology, TopologyConfig, link_stats,
                                 pathloss_amplitude)


@dataclasses.dataclass(frozen=True)
class ChannelProcessConfig:
    """Knobs of the round-indexed channel process (all off ⇒ paper-static)."""

    fading_rho: float = 1.0        # Gauss-Markov round-to-round correlation ρ
    shadowing_std_db: float = 0.0  # log-normal shadowing σ_sh (dB)
    shadowing_rho: float = 0.9     # AR(1) correlation of the shadowing (dB)
    speed: float = 0.0             # random-waypoint speed (m / round)
    csi_error_std: float = 0.0     # log-std of the water-filling gain error

    @property
    def evolves_geometry(self) -> bool:
        """True when the *channel itself* changes across rounds (fading,
        shadowing, mobility) — i.e. the engine must carry process state
        and re-derive the per-round channel view (needs a
        TopologyConfig).  CSI error alone does NOT qualify: it only
        perturbs the (K,) water-filling gains seen by the allocator."""
        return (self.fading_rho < 1.0 or self.shadowing_std_db > 0.0
                or self.speed > 0.0)

    @property
    def is_dynamic(self) -> bool:
        """True when any per-round re-derivation is needed (geometry
        evolution or per-round CSI redraws)."""
        return self.evolves_geometry or self.csi_error_std > 0.0


class ChannelState(NamedTuple):
    """Scan-carried state of the channel process."""

    positions: jnp.ndarray     # (K, 2) client positions
    waypoints: jnp.ndarray     # (K, 2) random-waypoint targets
    h_tilde: jnp.ndarray       # (K, K) complex small-scale fading, E|h|² = 1
    shadow_db: jnp.ndarray     # (K, K) symmetric shadowing (dB)


class ChannelView(NamedTuple):
    """One round's realized channel — the Topology fields that vary."""

    link_gain: jnp.ndarray     # (K, K) complex gains (diag = 0)
    link_snr: jnp.ndarray      # (K, K) |h|² P_ref / σ² (diag = 0)
    adjacency: jnp.ndarray     # (K, K) bool outage-pruned graph


def _symmetrize(m: jnp.ndarray, conj: bool) -> jnp.ndarray:
    """Mirror the strict upper triangle (channel reciprocity)."""
    K = m.shape[0]
    iu = jnp.triu(jnp.ones((K, K), bool), k=1)
    return jnp.where(iu, m, jnp.conj(m.T) if conj else m.T)


def _cn_symmetric(key: jax.Array, K: int) -> jnp.ndarray:
    """Symmetric CN(0, 1) draw — same convention as `make_topology`."""
    k_re, k_im = jax.random.split(key)
    re = jax.random.normal(k_re, (K, K)) / jnp.sqrt(2.0)
    im = jax.random.normal(k_im, (K, K)) / jnp.sqrt(2.0)
    return _symmetrize(re + 1j * im, conj=True)


def init_channel(topology: Topology, tcfg: TopologyConfig,
                 key: jax.Array) -> ChannelState:
    """Seed the process *at* the given stationary topology: the recovered
    fading state reproduces ``topology.link_gain`` exactly at round 0, so
    a process with all knobs off is the paper's channel, not merely a
    statistically equivalent one."""
    K = topology.num_clients
    pathloss = pathloss_amplitude(topology.positions, tcfg)
    h_tilde = jnp.where(jnp.eye(K, dtype=bool), 0.0,
                        topology.link_gain / pathloss)
    waypoints = jax.random.uniform(key, (K, 2)) * tcfg.area_size
    return ChannelState(positions=topology.positions, waypoints=waypoints,
                        h_tilde=h_tilde,
                        shadow_db=jnp.zeros((K, K), jnp.float32))


def step_channel(state: ChannelState, cfg: ChannelProcessConfig,
                 tcfg: TopologyConfig, key: jax.Array) -> ChannelState:
    """Advance the process one round (pure; scan-body safe)."""
    k_fade, k_shadow, k_way = jax.random.split(key, 3)
    K = state.positions.shape[0]

    # Random-waypoint mobility.
    to_target = state.waypoints - state.positions
    dist = jnp.sqrt(jnp.sum(to_target ** 2, axis=-1, keepdims=True) + 1e-12)
    arrived = dist[:, 0] <= cfg.speed
    step = jnp.minimum(cfg.speed / dist, 1.0) * to_target
    positions = state.positions + step
    fresh = jax.random.uniform(k_way, (K, 2)) * tcfg.area_size
    waypoints = jnp.where(arrived[:, None], fresh, state.waypoints)

    # Gauss-Markov Rayleigh fading (ρ = 1 ⇒ exactly static).
    rho = jnp.float32(cfg.fading_rho)
    innov = _cn_symmetric(k_fade, K)
    h_tilde = rho * state.h_tilde + jnp.sqrt(
        jnp.maximum(1.0 - rho ** 2, 0.0)) * innov

    # AR(1) log-normal shadowing in dB (stationary variance σ_sh²).
    rho_s = jnp.float32(cfg.shadowing_rho)
    n = _symmetrize(
        cfg.shadowing_std_db * jax.random.normal(k_shadow, (K, K)),
        conj=False)
    shadow_db = rho_s * state.shadow_db + jnp.sqrt(
        jnp.maximum(1.0 - rho_s ** 2, 0.0)) * n

    return ChannelState(positions=positions, waypoints=waypoints,
                        h_tilde=h_tilde, shadow_db=shadow_db)


def channel_view(state: ChannelState, tcfg: TopologyConfig) -> ChannelView:
    """Realize one round's gains/SNRs/graph from the process state via
    `make_topology`'s own helpers (`pathloss_amplitude`, `link_stats`) —
    reference equal-split power P/K, dB outage threshold, no self-links."""
    K = state.positions.shape[0]
    off = 1.0 - jnp.eye(K)
    amp = pathloss_amplitude(state.positions, tcfg) * (
        10.0 ** (state.shadow_db / 20.0))
    link_gain = amp * state.h_tilde * off
    link_snr, adjacency = link_stats(link_gain, tcfg)
    return ChannelView(link_gain=link_gain, link_snr=link_snr,
                       adjacency=adjacency)


def csi_perturbation(key: jax.Array, K: int, log_std: float) -> jnp.ndarray:
    """(K,) mean-one log-normal factor exp(σ z − σ²/2) for the
    water-filling gains — imperfect CSI at the power allocator."""
    z = jax.random.normal(key, (K,))
    return jnp.exp(log_std * z - 0.5 * log_std ** 2)
