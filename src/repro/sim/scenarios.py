"""Named scenario registry — the workloads the engine knows how to run.

A `Scenario` bundles a channel process, a participation schedule, a
re-clustering cadence and (optionally) an SNR grid for Monte-Carlo
sweeps.  `get_scenario(name)` resolves the registry; scenarios are plain
frozen dataclasses so CLIs / tests can also build ad-hoc ones.

Registry (see DESIGN.md §Sim for the math behind each knob):

* ``paper-static``    — the paper's §V protocol verbatim: stationary
  channel, full participation.  The engine's trajectory under this
  scenario is bit-identical to the pre-engine `run_federated` loop.
* ``mobile-fading``   — random-waypoint mobility + Gauss-Markov fading +
  log-normal shadowing + imperfect CSI (cf. arXiv 2207.09232's mobile
  hierarchical setting).
* ``straggler-heavy`` — 25% i.i.d. dropout plus three deterministic
  stragglers missing every third round, on the static channel.
* ``straggler-prox``  — the same harsh schedule with the scenario
  pinning the ``cwfl_prox`` strategy (paper §V's FedProx answer to
  partial participation / heterogeneity) as its registry-resolved
  default.
* ``snr-sweep``       — static channel, Monte-Carlo grid over overall
  SNR ξ ∈ {0, 10, 20, 30, 40} dB (the x-axis of the paper's noise-floor
  claims); `run_monte_carlo` vmaps the whole grid into one jit.
* ``cluster-churn``   — fading + mobility strong enough that the SNR
  landscape drifts, with periodic on-device re-clustering every 5 rounds
  (K-means + head election inside the scan, `lax.cond`-gated).
* ``head-failure``    — the paper's stated failure mode: Markov
  crash/recovery chains on every node (`repro.sim.faults`), so cluster
  heads / the COTAF server die mid-run and the strategy's
  ``on_head_failure`` handoff re-elects survivors.
* ``flaky-clients``   — the chaos kitchen sink: crashes, correlated
  dropout bursts, deep-fade blackouts AND scheduled i.i.d. dropout, with
  the divergence guard quarantining poisoned updates.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.sim.faults import FaultConfig
from repro.sim.processes import ChannelProcessConfig
from repro.sim.scheduling import ScheduleConfig
from repro.strategies import get_strategy


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str = "paper-static"
    channel: ChannelProcessConfig = ChannelProcessConfig()
    schedule: ScheduleConfig = ScheduleConfig()
    faults: FaultConfig = FaultConfig()   # node crash/burst/blackout process
    recluster_every: int = 0              # re-run clustering every n rounds (0=never)
    snr_grid: Tuple[float, ...] = ()      # Monte-Carlo SNR axis (dB); () = cfg.snr_db
    #: Default strategy for this scenario, resolved through the
    #: `repro.strategies` registry (``None`` = caller's choice).  CLIs use
    #: it when no ``--strategy`` is given; ``FLConfig.strategy`` always
    #: wins inside the engine.
    strategy: Optional[str] = None

    @property
    def is_static(self) -> bool:
        """True ⇒ the engine takes the bit-exact paper-static fast path."""
        return (not self.channel.is_dynamic and self.schedule.is_trivial
                and self.faults.is_trivial and self.recluster_every <= 0)

    def default_strategy(self, fallback: str = "cwfl"):
        """The scenario's preferred `Strategy` object (registry-resolved),
        or ``fallback``'s when the scenario doesn't pin one."""
        return get_strategy(self.strategy or fallback)


SCENARIOS = {
    "paper-static": Scenario(),
    "mobile-fading": Scenario(
        name="mobile-fading",
        channel=ChannelProcessConfig(fading_rho=0.9, shadowing_std_db=4.0,
                                     shadowing_rho=0.9, speed=2.0,
                                     csi_error_std=0.1)),
    "straggler-heavy": Scenario(
        name="straggler-heavy",
        schedule=ScheduleConfig(dropout_prob=0.25, num_stragglers=3,
                                straggler_period=3)),
    "straggler-prox": Scenario(
        name="straggler-prox",
        schedule=ScheduleConfig(dropout_prob=0.25, num_stragglers=3,
                                straggler_period=3),
        strategy="cwfl_prox"),
    "snr-sweep": Scenario(
        name="snr-sweep",
        snr_grid=(0.0, 10.0, 20.0, 30.0, 40.0)),
    "cluster-churn": Scenario(
        name="cluster-churn",
        channel=ChannelProcessConfig(fading_rho=0.95, speed=4.0,
                                     shadowing_std_db=2.0),
        recluster_every=5),
    "head-failure": Scenario(
        name="head-failure",
        faults=FaultConfig(crash_prob=0.15, recover_prob=0.3)),
    "flaky-clients": Scenario(
        name="flaky-clients",
        schedule=ScheduleConfig(dropout_prob=0.1),
        faults=FaultConfig(crash_prob=0.05, recover_prob=0.5,
                           burst_prob=0.2, burst_recover_prob=0.5,
                           burst_frac=0.5, deep_fade_prob=0.05,
                           deep_fade_rounds=2, divergence_guard=True,
                           quarantine_norm=100.0)),
}


def get_scenario(name: str) -> Scenario:
    if name not in SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"choose from {sorted(SCENARIOS)}")
    return SCENARIOS[name]
