"""Device-parallel execution of the scenario engine (DESIGN.md §Sharded-MC).

`repro.sim.engine.run_monte_carlo` batches the whole seeds × SNR grid onto
ONE device with ``vmap``; this module distributes the same traced
trajectory body (`engine.make_trajectory_fn` — shared, not re-derived)
across the mesh:

* ``monte_carlo_sharded`` — the trajectory grid is flattened seed-major,
  padded up to the ``("mc",)`` mesh axis size, and run under
  ``shard_map``: each device vmaps its own chunk of trajectories with
  per-trajectory metric buffers staying on that device until the single
  gather implied by the ``P("mc")`` out-spec.  Trajectories are
  embarrassingly parallel, so the body contains no collective at all —
  the sharded sweep computes exactly what the single-device vmap sweep
  computes (parity is pinned bitwise by ``tests/test_sim_sharded.py``;
  see DESIGN.md §Sharded-MC for why batch-size-dependent XLA fusion is
  the only thing that could ever split them).

* ``run_rounds_client_sharded`` — within ONE large-K trajectory, the
  stacked client axis is split over a ``("clients",)`` mesh
  (`repro.dist.sharding_rules.client_specs`): each rank trains its K/n
  clients locally and the CWFL sync runs as a two-phase collective in the
  mold of `repro.dist.fl_integration.hierarchical_ota_allreduce` — the
  per-cluster OTA sums ride a masked ``psum`` over the client axis
  (phase 1), the tiny inter-head consensus mix stays rank-local
  (phase 2), and each rank applies only its own rows of the phase-3
  downlink.  Channel-noise keys are replicated, so every rank sees the
  same channel realization, exactly like the hierarchical collective.
  Parity with the unsharded engine is *ulp-level*, not bitwise: the
  ``psum`` re-associates the over-the-air superposition Σ_k Ã_ck θ_k
  (and the gathered precoding norms) across ranks — documented in
  DESIGN.md §Sharded-MC and pinned with tolerances in the tests.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import cwfl
from repro.dist import shard_map
from repro.dist.sharding_rules import client_specs, trajectory_specs
from repro.launch.mesh import make_client_mesh, make_mc_mesh
from repro.models.small import accuracy as _accuracy
from repro.obs.telemetry import RoundTelemetry, init_ledger, per_client_dim
from repro.sim.engine import _SCAN_UNROLL, make_round_local_runner
from repro.sim.scenarios import Scenario
from repro.strategies import get_strategy
from repro.training.federated import FLConfig


# ---------------------------------------------------------------------------
# Trajectory-parallel Monte-Carlo (shard="mc").
# ---------------------------------------------------------------------------

def _pad_to(x: jnp.ndarray, n: int) -> jnp.ndarray:
    """Pad the leading axis up to ``n`` by repeating the last entry (the
    padded trajectories are real but redundant work, sliced off after the
    gather — a uniform per-device workload beats a ragged one)."""
    short = n - x.shape[0]
    if short <= 0:
        return x
    return jnp.concatenate([x, jnp.broadcast_to(x[-1], (short,) + x.shape[1:])])


def make_sharded_sweep_fn(traj, n_pad: int, rounds: int, mesh,
                          snr_db=None, with_grid: bool = False,
                          telemetry: bool = False):
    """Build the jitted ``shard_map`` sweep over ``n_pad`` flattened
    trajectories (``n_pad`` must divide over the ``mc`` axis).

    Returns ``f(seed_flat[, snr_flat]) -> (loss, acc)`` of shape
    ``(n_pad, rounds)`` each — plus the trajectory-batched
    `RoundTelemetry` when ``telemetry`` (a telemetry-enabled ``traj``
    returns a third element; its out-specs are derived from the traced
    output shapes via ``eval_shape``, leading trajectory dim over
    ``mc``).  Build ONCE and reuse — every call to this factory traces
    and compiles afresh (the bench measures steady-state throughput on
    the returned callable).
    """
    in_spec = trajectory_specs(
        jax.ShapeDtypeStruct((n_pad,), jnp.int32), mesh)
    out_spec = trajectory_specs(
        jax.ShapeDtypeStruct((n_pad, rounds), jnp.float32), mesh)

    # check_rep=False: the body is collective-free (rep checking has
    # nothing to verify) and the fused CWFL pallas_call has no
    # replication rule.
    if with_grid:
        body = lambda s, g: jax.vmap(traj)(s, g)
        in_specs: tuple = (in_spec, in_spec)
        eval_args = (jax.ShapeDtypeStruct((n_pad,), jnp.int32),
                     jax.ShapeDtypeStruct((n_pad,), jnp.float32))
    else:
        # snr_db may be a plain float or None — keep it a closure constant
        # exactly like the vmap path's in_axes=(0, None).
        body = lambda s: jax.vmap(lambda z: traj(z, snr_db))(s)
        in_specs = (in_spec,)
        eval_args = (jax.ShapeDtypeStruct((n_pad,), jnp.int32),)
    if telemetry:
        # Fit specs from the real (loss, acc, telemetry) output pytree —
        # only on the telemetry path, so the untelemetered sweep keeps
        # its hand-built specs (and jaxpr) untouched.
        out_specs = trajectory_specs(jax.eval_shape(body, *eval_args), mesh)
    else:
        out_specs = (out_spec, out_spec)
    return jax.jit(shard_map(
        body, mesh=mesh, in_specs=in_specs,
        out_specs=out_specs, check_rep=False))


def monte_carlo_sharded(traj, seeds: jnp.ndarray, snr_grid, snr_db,
                        rounds: int, mesh=None, telemetry: bool = False,
                        stream=None):
    """Run the flattened seeds × SNR grid under ``shard_map`` on the ``mc``
    mesh axis.

    ``traj`` is the engine's shared per-trajectory closure
    (`engine.make_trajectory_fn`).  Returns ``(loss, acc, grid)`` with the
    same shapes/dtypes as the vmap path: (S, T) when ``snr_grid`` is
    empty, else (S, G, T) in seed-major grid order.  With ``telemetry``
    (``traj`` must be a telemetry-enabled build) the return grows a
    fourth element — the `RoundTelemetry` pytree with (S,[G,]T) leading
    axes, unpadded and grid-reshaped exactly like the metric buffers.

    ``stream``: when ``traj`` carries a stream tap
    (`run_monte_carlo`'s post-scan `stream_trajectory_tap` wrapper —
    unordered, since the tap sits under the per-device vmap), the
    stream is scoped to rank 0's contiguous trajectory chunk by
    ``(seed, snr)`` tag before launch — "rank-0 emit" without a
    trace-time axis name, which would break the `eval_shape` the sweep
    factory uses for telemetry out-specs (``lax.axis_index`` is unbound
    outside the mesh body).
    """
    if mesh is None:
        mesh = make_mc_mesh()
    if "mc" not in mesh.axis_names:
        raise ValueError(
            f"shard='mc' needs a mesh with an ('mc',) axis "
            f"(launch.mesh.make_mc_mesh); got axes {mesh.axis_names}")
    n_dev = dict(mesh.shape)["mc"]
    S = int(seeds.shape[0])

    if snr_grid is not None and len(snr_grid) > 0:
        grid = jnp.asarray(snr_grid, jnp.float32)
        G = int(grid.shape[0])
        # seed-major flattening: pair i = (seed[i // G], grid[i % G]) — the
        # same order vmap(seeds) ∘ vmap(grid) fills (S, G), so the reshape
        # below is a pure relabeling.
        seed_flat = jnp.repeat(seeds, G)
        snr_flat = jnp.tile(grid, S)
    else:
        grid, G = None, 0
        seed_flat = seeds
        snr_flat = None

    n = int(seed_flat.shape[0])
    n_pad = -(-n // n_dev) * n_dev
    seed_flat = _pad_to(seed_flat, n_pad)

    if stream is not None:
        # Rank-0 emit: shard_map splits the flat trajectory axis into
        # contiguous per-device chunks, so rank 0 owns the first
        # n_pad / n_dev trajectories — scope the host stream to their
        # (seed, snr) tags (padding repeats the LAST entry, so rank 0's
        # chunk is all-real whenever it holds any real trajectory).
        chunk = n_pad // n_dev
        seeds_np = np.asarray(seed_flat)[:min(chunk, n)]
        if snr_flat is not None:
            snrs_np = np.asarray(snr_flat)[:min(chunk, n)]
            stream.scope_to_trajectories(zip(seeds_np, snrs_np))
        else:
            snr0 = None if snr_db is None else float(np.float32(snr_db))
            stream.scope_to_trajectories(
                (s, snr0) for s in seeds_np)

    f = make_sharded_sweep_fn(traj, n_pad, rounds, mesh, snr_db=snr_db,
                              with_grid=snr_flat is not None,
                              telemetry=telemetry)
    args = ((seed_flat,) if snr_flat is None
            else (seed_flat, _pad_to(snr_flat, n_pad)))
    if telemetry:
        loss, acc, tele = f(*args)
        tele = jax.tree.map(lambda x: x[:n], tele)
    else:
        loss, acc = f(*args)
    if stream is not None:
        jax.block_until_ready(loss)
        jax.effects_barrier()

    loss, acc = loss[:n], acc[:n]
    if grid is not None:
        loss = loss.reshape(S, G, rounds)
        acc = acc.reshape(S, G, rounds)
        if telemetry:
            tele = jax.tree.map(
                lambda x: x.reshape((S, G) + x.shape[1:]), tele)
    if telemetry:
        return loss, acc, grid, tele
    return loss, acc, grid


# ---------------------------------------------------------------------------
# Client-parallel single trajectory (shard="clients").
# ---------------------------------------------------------------------------

# Extras keys `_client_sharded_sync(with_telemetry=True)` reports (minus
# ``consensus_drift``, which feeds the RoundTelemetry field directly) —
# the shard_map out-spec layout for the telemetry pytree.
_CLIENT_TELE_EXTRAS = ("client_power", "noise_energy", "phase1_noise_std",
                       "phase2_noise_std", "power_budget_frac",
                       "precode_scale", "tx_power")

def _client_sharded_sync(stacked_local, state, key: jax.Array, axis: str,
                         with_telemetry: bool = False):
    """One CWFL sync with the K clients split over ``axis``.

    The K'-clients-per-rank generalization of
    `repro.dist.fl_integration.hierarchical_ota_allreduce`: phase 1's
    per-cluster OTA sums ride ``psum`` (the superposition over clients IS
    the collective), phase 2's (C, C) consensus mix is rank-local, and
    phase 3 applies only this rank's rows of the downlink matrix.  Noise
    streams replicate `cwfl._aggregate_flat`'s per-leaf key schedule with
    shared keys, so every rank sees the identical channel realization and
    the only divergence from the unsharded flat path is the ``psum``'s
    cross-rank re-association (ulp-level; DESIGN.md §Sharded-MC).

    ``with_telemetry`` additionally returns the sync's internals as a
    third element — the same extras dict keys `CWFLStrategy.telemetry`
    reports on the unsharded path, plus ``consensus_drift`` (per-head
    ‖θ̄_c − θ̄‖, already replicated across ranks by the psum).
    """
    leaves, treedef = jax.tree.flatten(stacked_local)
    kl = leaves[0].shape[0]
    C = state.num_clusters
    k1, k2 = jax.random.split(key)

    flat = cwfl._flat_pack(leaves, kl)
    d = flat.shape[1]

    # eq. (5) precoding needs every client's per-channel-use power: gather
    # the (K',) local norms into the global (K,) vector on every rank.
    sq_local = jnp.sum(flat * flat, axis=1)
    mean_sq = jax.lax.all_gather(sq_local, axis, tiled=True) / d
    A, eff_std1, B, kappa, m_back = cwfl.round_coefficients(
        state, None, mean_sq=mean_sq)

    r = jax.lax.axis_index(axis)
    a_loc = jax.lax.dynamic_slice_in_dim(A, r * kl, kl, axis=1)   # (C, K')

    # Phase 1 (eq. 8): the OTA MAC — per-cluster sums over all K clients
    # ride the mesh collective; receiver AWGN is shared-key replicated.
    theta_tilde = jax.lax.psum(a_loc @ flat, axis)                # (C, d)
    theta_tilde = theta_tilde + cwfl._flat_leaf_noise(
        k1, leaves, C, eff_std1)

    # Phase 2 (eq. 9 / lemma 2): tiny (C, C) mix, rank-local.
    theta_bar = B @ theta_tilde + cwfl._flat_leaf_noise(k2, leaves, C, kappa)

    # Phase 3: error-free downlink — this rank's clients only.
    m_loc = jax.lax.dynamic_slice_in_dim(m_back, r * kl, kl, axis=0)
    new_flat = m_loc @ theta_bar                                  # (K', d)
    cons_flat = jnp.mean(theta_bar, axis=0)                       # (d,)
    new, cons = cwfl._flat_unpack(new_flat, cons_flat, leaves, treedef, kl)
    if not with_telemetry:
        return new, cons
    pre = cwfl.precode_scale(state, mean_sq)
    member = 1.0 - state.plan.head_mask
    tx_power = (member * (state.client_power / state.total_power)
                * pre**2 * mean_sq)
    extras = {
        "consensus_drift": jnp.sqrt(jnp.sum(
            jnp.square(theta_bar - cons_flat[None, :]), axis=1)),
        "precode_scale": pre,
        "client_power": state.client_power,
        "tx_power": tx_power,
        "power_budget_frac": jnp.sum(tx_power) / state.total_power,
        "phase1_noise_std": eff_std1,
        "phase2_noise_std": kappa,
        "noise_energy": d * (jnp.sum(eff_std1**2) + jnp.sum(kappa**2)),
    }
    return new, cons, extras


def run_rounds_client_sharded(init_fn, apply_fn, loss_fn, topology,
                              xs: jnp.ndarray, ys: jnp.ndarray,
                              x_test: jnp.ndarray, y_test: jnp.ndarray,
                              cfg: FLConfig,
                              scenario: Optional[Scenario] = None,
                              mesh=None,
                              telemetry: bool = False,
                              checkpoint_dir: Optional[str] = None,
                              checkpoint_every: int = 0,
                              resume: bool = False,
                              resume_step: Optional[int] = None,
                              stop_after: Optional[int] = None,
                              stream=None) -> dict[str, Any]:
    """One trajectory with the stacked K-client axis sharded over a
    ``("clients",)`` mesh: per-rank local training (vmap over K/n local
    clients) + the `psum`-riding CWFL sync, scanned over rounds.

    Static CWFL scenarios only — the per-round state rebuilds of dynamic
    scenarios replicate fine, but masking/re-clustering haven't been
    taught the sharded sync yet (raise rather than silently diverge).
    The carry and key schedule come from `engine._build`'s own eager
    ``prepare`` (not a copy), so they track the unsharded path by
    construction; metrics agree to psum-reassociation tolerance.

    ``telemetry=True`` (static flag) emits ``history["telemetry"]`` with
    the same `RoundTelemetry` fields as the unsharded engine: per-cluster
    losses ride one extra tiny ``psum`` (membership-sliced (C, K') @
    local losses), everything else falls out of the sync's own
    replicated internals (`_client_sharded_sync`'s extras).

    ``checkpoint_dir``/``checkpoint_every``/``resume``/``resume_step``/
    ``stop_after``: chunked checkpoint/resume with the same contract as
    `engine.run_rounds` — the scan is split into segments and the full
    carry (sharded param/opt stacks gathered to host, consensus, ledger)
    is persisted at each boundary, manifest-stamped (the manifest's
    strategy field carries an ``@clients`` suffix so sharded and
    unsharded checkpoints — equal only to psum-reassociation ulps —
    can never be spliced).  With checkpointing off the traced
    computation is byte-identical to before (static-flag discipline).

    ``stream`` (STATIC, needs ``telemetry=True``): a
    `repro.obs.stream.RoundStream` tapped from inside the shard_map'd
    scan body — every rank fires the callback on its replicated round
    values and passes ``lax.axis_index("clients")`` along, and the host
    keeps rank 0 only (effects cannot hide behind a traced `lax.cond`),
    so the stream carries exactly one record per round.  The callback
    is unordered (an ordered effect token inside a jitted shard_map
    aborts XLA's sharding propagation on this toolchain); each record's
    absolute round tag carries the ordering instead.
    """
    from repro.sim.engine import _build, checkpoint_manifest

    scenario = scenario or Scenario()
    ckpt = checkpoint_dir is not None
    streaming = stream is not None
    if not ckpt and (resume or stop_after is not None):
        raise ValueError(
            "resume/stop_after need checkpoint_dir — there is nothing to "
            "restore from or checkpoint into")
    if streaming:
        if not telemetry:
            raise ValueError(
                "stream= drains RoundTelemetry live and needs "
                "telemetry=True")
        if stream.escalates and not ckpt:
            raise ValueError(
                "abort-on-alert escalates via the checkpoint machinery "
                "(checkpoint-then-stop, resumable); pass checkpoint_dir")
    if not scenario.is_static:
        raise NotImplementedError(
            "shard='clients' supports static scenarios only (dynamic "
            "masking/re-clustering haven't been taught the sharded sync)")
    strategy = get_strategy(cfg.strategy)
    if not strategy.supports_client_sharding:
        raise NotImplementedError(
            f"shard='clients' needs a strategy whose sync is implemented "
            f"as a client-axis mesh collective (supports_client_sharding); "
            f"{type(strategy).__name__} (strategy {cfg.strategy!r}) has "
            f"none")
    if mesh is None:
        mesh = make_client_mesh()
    if "clients" not in mesh.axis_names:
        raise ValueError(
            f"shard='clients' needs a mesh with a ('clients',) axis "
            f"(launch.mesh.make_client_mesh); got axes {mesh.axis_names}")
    n_dev = dict(mesh.shape)["clients"]
    K, n_k = int(xs.shape[0]), int(xs.shape[1])
    if K % n_dev:
        raise ValueError(
            f"K={K} clients must divide over the {n_dev}-way clients axis")
    kl = K // n_dev
    T = cfg.rounds

    # EAGER prepare — the engine's own (bit-identity-protected) setup and
    # PRNG schedule; a static scenario's ctx IS the strategy state.
    prepare, _ = _build(init_fn, apply_fn, loss_fn, topology, xs, ys,
                        x_test, y_test, cfg, scenario, None)
    state0, carry0, scan_xs = prepare(cfg.seed, cfg.snr_db)
    stacked, opt_state = carry0["stacked"], carry0["opt"]
    params0 = carry0["consensus"]
    round_keys = scan_xs["rkey"]

    _, local_run = make_round_local_runner(loss_fn, cfg, n_k)
    x_ev = x_test[: cfg.eval_samples]
    y_ev = y_test[: cfg.eval_samples]

    membership = state0.plan.membership                  # (C, K), static
    counts = jnp.maximum(membership.sum(axis=1), 1.0)
    uses = jnp.asarray(
        strategy.channel_uses(K, num_clusters=cfg.num_clusters),
        jnp.float32)

    def traj(stacked0, opt0, cons0, xs_l, ys_l, rkeys, *extra):
        # extra = ([sts] when streaming) + ([ledger0] on the checkpointed
        # telemetry path) — absolute round indices for the stream tap
        # (sliced alongside rkeys by the segment driver, so a resumed
        # stream keeps absolute rounds) and the cumulative channel-use
        # ledger that must survive a resume.
        extra = list(extra)
        sts = extra.pop(0) if streaming else None
        r = jax.lax.axis_index("clients")

        def body(carry, inp):
            if streaming:
                rkey, st_t = inp
            else:
                rkey, st_t = inp, None
            if telemetry:
                st, opt, _, ledger = carry
            else:
                st, opt, _ = carry
            k_local, k_agg = jax.random.split(rkey)
            client_keys = jax.random.split(k_local, K)   # global schedule
            ck = jax.lax.dynamic_slice_in_dim(client_keys, r * kl, kl)
            st, opt, losses = jax.vmap(local_run)(st, opt, xs_l, ys_l, ck)
            if telemetry:
                new, consensus, extras = _client_sharded_sync(
                    st, state0, k_agg, "clients", with_telemetry=True)
            else:
                new, consensus = _client_sharded_sync(st, state0, k_agg,
                                                      "clients")
            loss = jax.lax.psum(jnp.sum(losses), "clients") / K
            logits = apply_fn(consensus, x_ev)
            acc = _accuracy(logits, y_ev)
            if not telemetry:
                return (new, opt, consensus), (loss, acc)
            mem_loc = jax.lax.dynamic_slice_in_dim(membership, r * kl, kl,
                                                   axis=1)     # (C, K')
            # Fresh full-shard losses for telemetry — reading the
            # minibatch `losses` again would re-fuse its psum-mean and
            # perturb the reported train_loss by ulps (same contract as
            # the unsharded engine body).
            tele_losses = jax.vmap(loss_fn)(st, xs_l, ys_l)
            cluster_loss = jax.lax.psum(mem_loc @ tele_losses,
                                        "clients") / counts
            d = per_client_dim(st)
            new_ledger = {"uses": ledger["uses"] + uses,
                          "symbols": ledger["symbols"] + uses * d}
            tele = RoundTelemetry(
                cluster_loss=cluster_loss,
                participants=jnp.asarray(K, jnp.float32),
                consensus_drift=extras.pop("consensus_drift"),
                channel_uses=uses,
                cum_channel_uses=new_ledger["uses"],
                cum_symbols=new_ledger["symbols"],
                reclustered=jnp.zeros((), jnp.float32),
                extras=extras)
            if streaming:
                # In-body tap on replicated round values; the axis index
                # rides the payload and the host drops ranks != 0.
                # UNORDERED: an ordered effect token inside a jitted
                # shard_map trips XLA's sharding-propagation parameter
                # check (hard abort at compile time on this toolchain) —
                # the absolute round tag in the payload carries the
                # ordering instead, and consumers sort by it.
                from repro.obs.stream import stream_tap
                stream_tap(stream, t=st_t, seed=cfg.seed, snr=cfg.snr_db,
                           loss=loss, acc=acc, telemetry=tele, rank=r,
                           ordered=False)
            return (new, opt, consensus, new_ledger), (loss, acc, tele)

        xs_scan = (rkeys, sts) if streaming else rkeys
        if telemetry:
            ledger0 = extra.pop(0) if ckpt else init_ledger()
            (st_f, opt_f, final, ledger_f), out = jax.lax.scan(
                body, (stacked0, opt0, cons0, ledger0), xs_scan,
                unroll=_SCAN_UNROLL)
            loss, acc, tele = out
            if ckpt:
                return loss, acc, final, tele, st_f, opt_f, ledger_f
            return loss, acc, final, tele
        (st_f, opt_f, final), (loss, acc) = jax.lax.scan(
            body, (stacked0, opt0, cons0), xs_scan, unroll=_SCAN_UNROLL)
        if ckpt:
            return loss, acc, final, st_f, opt_f
        return loss, acc, final

    # Specs come from the dist rules layer: leading K over "clients" for
    # every stacked leaf, replication for everything per-rank identical.
    k_spec = lambda tree: client_specs(jax.eval_shape(lambda t: t, tree),
                                       mesh)
    rep = lambda tree: jax.tree.map(lambda _: P(), tree)
    ledger0 = init_ledger() if telemetry else None
    sts_full = jnp.arange(T, dtype=jnp.int32) if streaming else None
    in_specs: tuple = (k_spec(stacked), k_spec(opt_state), rep(params0),
                       P("clients"), P("clients"), P())
    if streaming:
        in_specs = in_specs + (P(),)          # sts: replicated round tags
    out_specs: tuple = (P(), P(), rep(params0))
    if telemetry:
        # Every telemetry value is psum-replicated or a rank-constant —
        # all-P() specs, keyed off the known extras layout.
        tele_spec = RoundTelemetry(
            cluster_loss=P(), participants=P(), consensus_drift=P(),
            channel_uses=P(), cum_channel_uses=P(), cum_symbols=P(),
            reclustered=P(),
            extras={k: P() for k in _CLIENT_TELE_EXTRAS})
        out_specs = out_specs + (tele_spec,)
    if ckpt:
        out_specs = out_specs + (k_spec(stacked), k_spec(opt_state))
        if telemetry:
            in_specs = in_specs + (rep(ledger0),)
            out_specs = out_specs + (rep(ledger0),)
    f = shard_map(
        traj, mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        check_rep=False)   # scan+psum bodies defeat the rep checker
    fj = jax.jit(f)

    tele = None
    if not ckpt:
        args = (stacked, opt_state, params0, xs, ys, round_keys)
        if streaming:
            args = args + (sts_full,)
        out = fj(*args)
        if streaming:
            jax.block_until_ready(out)
            jax.effects_barrier()
        if telemetry:
            loss, acc, consensus, tele = out
        else:
            loss, acc, consensus = out
    else:
        loss, acc, consensus, tele = _client_sharded_checkpointed(
            fj, stacked, opt_state, params0, ledger0, xs, ys, round_keys,
            T, cfg, scenario, strategy, telemetry=telemetry,
            checkpoint_dir=checkpoint_dir, checkpoint_every=checkpoint_every,
            resume=resume, resume_step=resume_step, stop_after=stop_after,
            manifest_fn=checkpoint_manifest, stream=stream,
            sts_full=sts_full)

    history = {
        "round": np.arange(1, int(loss.shape[0]) + 1),
        "train_loss": loss,
        "test_acc": acc,
        "final_params": consensus,
        "avg_acc": jnp.mean(acc),
        "final_acc": acc[-1],
    }
    if telemetry:
        history["telemetry"] = tele
    return history


def _client_sharded_checkpointed(fj, stacked, opt_state, params0, ledger0,
                                 xs, ys, round_keys, T: int, cfg, scenario,
                                 strategy, *, telemetry: bool,
                                 checkpoint_dir, checkpoint_every: int,
                                 resume: bool, resume_step, stop_after,
                                 manifest_fn, stream=None, sts_full=None):
    """Segment driver for the checkpointed client-sharded trajectory —
    the `engine._run_scan_checkpointed` contract on the shard_map path:
    run ``checkpoint_every``-round chunks, persist the full carry +
    accumulated metrics at each boundary, restore and continue on
    ``resume`` (bitwise — the chunked scan is the same per-round body).
    """
    from pathlib import Path

    from repro.checkpoint import (latest_step, load_checkpoint,
                                  save_checkpoint)

    directory = Path(checkpoint_dir)
    every = (T if checkpoint_every is None or int(checkpoint_every) <= 0
             else min(int(checkpoint_every), T))
    # "@clients" keys the manifest hash: sharded and unsharded histories
    # agree only to psum-reassociation ulps — never splice them.
    manifest_fn(directory, cfg, scenario, strategy.name + "@clients",
                resume)

    streaming = stream is not None

    def call(st, opt, cons, ld, keys, sts_seg):
        args = (st, opt, cons, xs, ys, keys)
        if streaming:
            args = args + (sts_seg,)
        if telemetry:
            args = args + (ld,)
        return fj(*args)

    def out_template(n):
        # Abstract-evaluate the jitted shard_map fn for an n-round chunk:
        # the (loss, acc[, telemetry]) accumulator template for resume.
        args = (stacked, opt_state, params0, xs, ys, round_keys[:n])
        if streaming:
            args = args + (sts_full[:n],)
        if telemetry:
            args = args + (ledger0,)
        shapes = jax.eval_shape(fj, *args)
        sub = ((shapes[0], shapes[1], shapes[3]) if telemetry
               else (shapes[0], shapes[1]))
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), sub)

    st, opt, cons, ld = stacked, opt_state, params0, ledger0
    start, acc_out = 0, None
    if resume:
        step = (resume_step if resume_step is not None
                else latest_step(directory))
        if step is None:
            raise FileNotFoundError(
                f"resume: no checkpoint steps in {directory}")
        if not 0 < step <= T:
            raise ValueError(
                f"resume: checkpoint step {step} outside this run's "
                f"1..{T} round range")
        template = {"stacked": stacked, "opt": opt_state,
                    "consensus": params0, "out": out_template(step)}
        if telemetry:
            template["ledger"] = ledger0
        payload = load_checkpoint(directory, template, step=step)
        st, opt, cons = (payload["stacked"], payload["opt"],
                         payload["consensus"])
        ld = payload.get("ledger", ledger0)
        acc_out, start = payload["out"], int(step)

    pos = start
    while pos < T:
        end = min(pos + every, T)
        res = call(st, opt, cons, ld, round_keys[pos:end],
                   sts_full[pos:end] if streaming else None)
        if telemetry:
            loss_s, acc_s, cons, tele_s, st, opt, ld = res
            seg = (loss_s, acc_s, tele_s)
        else:
            loss_s, acc_s, cons, st, opt = res
            seg = (loss_s, acc_s)
        acc_out = seg if acc_out is None else jax.tree.map(
            lambda a, b: jnp.concatenate([a, b], axis=0), acc_out, seg)
        pos = end
        payload = {"stacked": st, "opt": opt, "consensus": cons,
                   "out": acc_out}
        if telemetry:
            payload["ledger"] = ld
        save_checkpoint(directory, pos, payload)
        if stop_after is not None and pos >= int(stop_after) and pos < T:
            break
        if streaming:
            jax.effects_barrier()   # drain the segment before polling
            if stream.should_abort and pos < T:
                break

    if telemetry:
        return acc_out[0], acc_out[1], cons, acc_out[2]
    return acc_out[0], acc_out[1], cons, None
