"""Per-round client participation (dropouts, stragglers, energy budgets).

A round's participation mask m_t ∈ {0,1}^K is folded into the OTA round
coefficients by `repro.core.cwfl.round_coefficients(mask=...)` /
`baselines.cotaf_aggregate(mask=...)`: absent clients get a zero column in
the phase-1 amplitude matrix Ã *before* the convex renormalization, so
they neither transmit power nor bias the superposition, and the effective
receiver noise renormalizes by the (smaller) present-member row sum.
Cluster-heads are always present (see `cwfl.participation_weights`).

Three independent mechanisms compose (logical AND):

* **Bernoulli dropout** — each client independently absent w.p. p_drop
  (fast fading of the control link / app-level jitter).
* **Deterministic stragglers** — clients 0..S−1 miss every round with
  t ≡ period−1 (mod period): the reproducible worst case for debugging
  and for the `straggler-heavy` scenario.
* **Energy budgets** — each client can afford ``energy_budget``
  transmissions; once spent, it goes permanently silent (battery death).
  Participation decrements the budget; sitting out doesn't.  The budget
  tracks *scheduled* member uplinks only: cluster-heads/servers that the
  aggregation layer forces present (`cwfl.participation_weights`,
  `baselines.cotaf_participation`) act as receivers whose phase-2 /
  local costs sit outside this model, so a forced-present round is not
  charged.

State is a NamedTuple pytree so it rides the engine's scan carry.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ScheduleConfig:
    dropout_prob: float = 0.0     # per-round i.i.d. absence probability
    num_stragglers: int = 0       # clients 0..S-1 straggle deterministically
    straggler_period: int = 0     # straggle when t % period == period-1 (0=off)
    energy_budget: float = 0.0    # max participations per client (0 = ∞)

    @property
    def is_trivial(self) -> bool:
        """True when every mechanism is off ⇒ the engine skips masking
        entirely (bit-identical to the pre-mask code path)."""
        return (self.dropout_prob <= 0.0
                and (self.num_stragglers <= 0 or self.straggler_period <= 0)
                and self.energy_budget <= 0.0)


class ScheduleState(NamedTuple):
    energy_left: jnp.ndarray      # (K,) remaining transmissions (∞ = unbounded)


def init_schedule(cfg: ScheduleConfig, num_clients: int) -> ScheduleState:
    budget = cfg.energy_budget if cfg.energy_budget > 0 else jnp.inf
    return ScheduleState(
        energy_left=jnp.full((num_clients,), budget, jnp.float32))


def participation_mask(cfg: ScheduleConfig, state: ScheduleState,
                       t: jnp.ndarray, key: jax.Array, num_clients: int
                       ) -> Tuple[jnp.ndarray, ScheduleState]:
    """One round's mask. Returns ((K,) float {0,1}, new state)."""
    K = num_clients
    alive = state.energy_left > 0.0
    keep = jax.random.bernoulli(key, 1.0 - cfg.dropout_prob, (K,))
    if cfg.num_stragglers > 0 and cfg.straggler_period > 0:
        slow = jnp.arange(K) < cfg.num_stragglers
        late = (t % cfg.straggler_period) == (cfg.straggler_period - 1)
        straggle = slow & late
    else:
        straggle = jnp.zeros((K,), bool)
    mask = (alive & keep & ~straggle).astype(jnp.float32)
    return mask, ScheduleState(energy_left=state.energy_left - mask)
