"""InternVL2-2B: InternLM2-1.8B language decoder (24L, d=2048, 16H GQA kv=8,
d_ff=8192, vocab 92553) consuming InternViT patch embeddings through an MLP
projector. Vision encoder is a STUB: input_specs provides 256 precomputed
patch embeddings of dim 1024 (448px / 14 patch, 0.5 pixel-shuffle).
[arXiv:2404.16821]"""
from repro.models.config import ArchConfig, LayerSpec

config = ArchConfig(
    name="internvl2-2b",
    arch_type="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    pattern=(LayerSpec(mixer="attn", ffn="dense"),),
    frontend="vision_stub",
    frontend_dim=1024,
    prefix_tokens=256,
    source="arXiv:2404.16821",
)
