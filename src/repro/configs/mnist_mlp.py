"""The paper's MNIST model (§V): 4-layer MLP with ReLU, log-softmax head."""
config = {
    "kind": "mnist_mlp",
    "input_hw": (28, 28, 1),
    "hidden": (200, 100, 64),
    "num_classes": 10,
    "batch_size": 64,     # paper
    "lr": 1e-3,           # paper
    "clients": 50,        # paper
    "noniid_shards_per_client": 4,
}
