"""xLSTM-125M: 12L, d=768, 4 heads, sLSTM + mLSTM blocks (d_ff=0: mixers have
internal up-projections, no separate FFN), vocab 50304. [arXiv:2405.04517]

Period of 4: three mLSTM blocks then one sLSTM block (3 sLSTM layers total).
"""
from repro.models.config import ArchConfig, LayerSpec

_PERIOD = (
    LayerSpec(mixer="mlstm", ffn="none"),
    LayerSpec(mixer="mlstm", ffn="none"),
    LayerSpec(mixer="mlstm", ffn="none"),
    LayerSpec(mixer="slstm", ffn="none"),
)

config = ArchConfig(
    name="xlstm-125m",
    arch_type="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    pattern=_PERIOD,
    tie_embeddings=True,
    source="arXiv:2405.04517",
)
