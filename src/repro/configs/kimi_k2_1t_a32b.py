"""Kimi K2: 61L, d=7168, 64H (GQA kv=8), MoE 384 experts top-8 with expert
d_ff=2048, vocab 163840 — trillion-parameter MoE. [arXiv:2501.kimi2]
Deviation: K2's dense first layer and shared expert are folded into the
uniform MoE pattern (noted in DESIGN.md)."""
from repro.models.config import ArchConfig, LayerSpec

config = ArchConfig(
    name="kimi-k2-1t-a32b",
    arch_type="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    d_ff=0,
    d_ff_expert=2048,
    num_experts=384,
    top_k=8,
    vocab_size=163840,
    pattern=(LayerSpec(mixer="attn", ffn="moe"),),
    rope_theta=1_000_000.0,
    source="arXiv:2501.kimi2",
)
