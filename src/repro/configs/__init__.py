"""Assigned-architecture registry: ``--arch <id>`` resolves here.

Each module defines ``config: ArchConfig`` with the exact assigned dimensions
(source paper/model-card cited in ``config.source``). ``get_config(name)``
also accepts the reduced smoke variant via ``reduced=True``.
"""
from __future__ import annotations

import importlib

_ARCH_MODULES = {
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "xlstm-125m": "xlstm_125m",
    "internvl2-2b": "internvl2_2b",
    "gemma2-9b": "gemma2_9b",
    "whisper-tiny": "whisper_tiny",
    "llama3-405b": "llama3_405b",
    "qwen2.5-3b": "qwen2_5_3b",
    # the paper's own models
    "mnist-mlp": "mnist_mlp",
    "cifar-cnn": "cifar_cnn",
}

ARCH_NAMES = [n for n in _ARCH_MODULES if n not in ("mnist-mlp", "cifar-cnn")]


def get_config(name: str, reduced: bool = False):
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    cfg = mod.config
    return cfg.reduced() if reduced and hasattr(cfg, "reduced") else cfg
