"""Whisper-tiny: encoder-decoder, 4L each, d=384, 6H (kv=6), d_ff=1536, vocab
51865. Conv/mel frontend is a STUB: input_specs provides 1500 frames of dim
80 (post-conv sequence length), projected into d_model by the encoder.
[arXiv:2212.04356]"""
from repro.models.config import ArchConfig, LayerSpec

config = ArchConfig(
    name="whisper-tiny",
    arch_type="audio",
    num_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    pattern=(LayerSpec(mixer="attn", ffn="dense"),),
    encoder_layers=4,
    encoder_seq=1500,
    frontend="audio_stub",
    frontend_dim=80,
    norm="layernorm",
    source="arXiv:2212.04356",
)
