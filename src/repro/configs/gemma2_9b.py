"""Gemma-2 9B: 42L, d=3584, 16H (GQA kv=8, head_dim=256), d_ff=14336, vocab
256000, alternating local(4096-window)/global attention, attention softcap 50
and final-logit softcap 30, tied embeddings. [arXiv:2408.00118]

long_500k serving variant caps the *global* layers at a 32k window (noted
deviation; DESIGN.md §6)."""
from repro.models.config import ArchConfig, LayerSpec

_PERIOD = (
    LayerSpec(mixer="attn", window=4096, ffn="dense"),   # local
    LayerSpec(mixer="attn", window=0, ffn="dense"),      # global
)

config = ArchConfig(
    name="gemma2-9b",
    arch_type="dense",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    pattern=_PERIOD,
    softcap_attn=50.0,
    softcap_final=30.0,
    tie_embeddings=True,
    source="arXiv:2408.00118",
)
