"""Llama-3 405B: 126L, d=16384, 128H (GQA kv=8), d_ff=53248, vocab 128256,
RoPE theta 5e5. [arXiv:2407.21783]"""
from repro.models.config import ArchConfig, LayerSpec

config = ArchConfig(
    name="llama3-405b",
    arch_type="dense",
    num_layers=126,
    d_model=16384,
    num_heads=128,
    num_kv_heads=8,
    d_ff=53248,
    vocab_size=128256,
    pattern=(LayerSpec(mixer="attn", ffn="dense"),),
    rope_theta=500_000.0,
    source="arXiv:2407.21783",
)
