"""Phi-4-mini (3.8B): 32L, d=3072, 24H (GQA kv=8), d_ff=8192, vocab 200064,
RoPE + SwiGLU + GQA. [arXiv:2412.08905]"""
from repro.models.config import ArchConfig, LayerSpec

config = ArchConfig(
    name="phi4-mini-3.8b",
    arch_type="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=200064,
    pattern=(LayerSpec(mixer="attn", ffn="dense"),),
    tie_embeddings=True,
    source="arXiv:2412.08905",
)
