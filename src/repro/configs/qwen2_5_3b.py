"""Qwen2.5-3B: 36L, d=2048, 16H (GQA kv=2), d_ff=11008, vocab 151936, QKV
bias, tied embeddings. [hf:Qwen/Qwen2.5-0.5B family scaling]"""
from repro.models.config import ArchConfig, LayerSpec

config = ArchConfig(
    name="qwen2.5-3b",
    arch_type="dense",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    d_ff=11008,
    vocab_size=151936,
    pattern=(LayerSpec(mixer="attn", ffn="dense"),),
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen2.5-0.5B",
)
