"""Jamba v0.1 (52B): 32L, d=4096, 32H (GQA kv=8), d_ff=14336, MoE 16 experts
top-2, vocab 65536. Mamba:attention 7:1 interleave, MoE every other layer.
[arXiv:2403.19887]

Period of 8 layers: attention at position 4, Mamba elsewhere; MoE FFN on odd
positions, dense FFN on even — 4 periods = 32 layers.
"""
from repro.models.config import ArchConfig, LayerSpec

_PERIOD = tuple(
    LayerSpec(mixer=("attn" if i == 4 else "mamba"),
              ffn=("moe" if i % 2 == 1 else "dense"))
    for i in range(8)
)

config = ArchConfig(
    name="jamba-v0.1-52b",
    arch_type="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    d_ff_expert=14336,
    num_experts=16,
    top_k=2,
    vocab_size=65536,
    pattern=_PERIOD,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    source="arXiv:2403.19887",
)
