"""The paper's CIFAR model (§V): 6-layer CNN (3×64, 64×120, 120×200 convs
with 2×2 max-pool, log-softmax head)."""
config = {
    "kind": "cifar_cnn",
    "input_hw": (32, 32, 3),
    "num_classes": 10,
    "batch_size": 32,     # paper
    "lr": 1e-3,           # paper
    "clients": 27,        # paper
    "noniid_shards_per_client": 7,
}
