"""Qwen3-235B-A22B: 94L, d=4096, 64H (GQA kv=4, head_dim=128), MoE 128
experts top-8 with expert d_ff=1536, vocab 151936, QK-norm, no QKV bias.
[hf:Qwen/Qwen3-30B-A3B family scaling]"""
from repro.models.config import ArchConfig, LayerSpec

config = ArchConfig(
    name="qwen3-moe-235b-a22b",
    arch_type="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=0,
    d_ff_expert=1536,
    num_experts=128,
    top_k=8,
    vocab_size=151936,
    pattern=(LayerSpec(mixer="attn", ffn="moe"),),
    qk_norm=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-30B-A3B",
)
