import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × input-shape) combination
on the production meshes, WITHOUT allocating any real arrays.

Per combination this prints/records:
  * compile success,
  * memory analysis (bytes per device: arguments, temps, outputs),
  * cost analysis (HLO flops/bytes — per-scan-iteration, see roofline.py for
    the trip-count-corrected numbers),
  * the collective-op inventory parsed from the compiled HLO.

Usage:
  python -m repro.launch.dryrun --arch phi4-mini-3.8b --shape train_4k
  python -m repro.launch.dryrun --all --mesh pod1 --out results/dryrun.json
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_config
from repro.launch.mesh import make_production_mesh
from repro.models.config import INPUT_SHAPES, ArchConfig, InputShape
from repro.training import dist_steps as ds
from repro.utils import cost_analysis_dict


# ---------------------------------------------------------------------------
# long_500k policy (DESIGN.md §6): native for state-bounded archs, sliding-
# window serving variant for full-attention archs, skip whisper.
# ---------------------------------------------------------------------------

LONG_NATIVE = {"xlstm-125m", "jamba-v0.1-52b", "gemma2-9b"}
LONG_SWA = {"phi4-mini-3.8b", "qwen2.5-3b", "llama3-405b",
            "qwen3-moe-235b-a22b", "kimi-k2-1t-a32b", "internvl2-2b"}
LONG_SKIP = {"whisper-tiny": "enc-dec audio: 500k-token decode is "
                             "semantically void for 30s audio"}
SWA_WINDOW = 32768

DTYPE_OVERRIDES = dict(param_dtype="bfloat16", compute_dtype="bfloat16")

COLLECTIVE_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?!-done)\b")
SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def collective_bytes_of_line(line: str) -> int:
    """Sum result-shape bytes of a collective HLO line (output ≈ traffic
    proxy; all-reduce moves ~2× in a ring — accounted in roofline.py)."""
    head = line.split("=", 1)
    if len(head) < 2:
        return 0
    # result shapes appear between '=' and the op name
    m = COLLECTIVE_RE.search(line)
    if not m:
        return 0
    result_part = line[len(head[0]) + 1: m.start()]
    total = 0
    for dt, dims in SHAPE_RE.findall(result_part):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Inventory: op kind -> (count, bytes). Only top-level + loop bodies
    counted ONCE (per-iteration); roofline.py handles trip counts."""
    out: dict[str, list] = {}
    for line in hlo_text.splitlines():
        m = COLLECTIVE_RE.search(line)
        if not m or "=" not in line:
            continue
        kind = m.group(1)
        b = collective_bytes_of_line(line)
        if kind not in out:
            out[kind] = [0, 0]
        out[kind][0] += 1
        out[kind][1] += b
    return {k: {"count": v[0], "bytes": v[1]} for k, v in out.items()}


def prepare_cfg(arch: str, shape: InputShape, mesh, *,
                for_cost: bool = False, variant: str = "base") -> ArchConfig:
    import math
    opts = set(variant.split("+"))
    cfg = get_config(arch).replace(**DTYPE_OVERRIDES)
    dp = math.prod(mesh.shape[a] for a in mesh.axis_names if a != "model")
    cfg = cfg.replace(moe_shards=dp)   # shard-local MoE dispatch
    batch_axes = ("pod", "data") if "pod" in mesh.axis_names else "data"
    if shape.global_batch % dp != 0:   # long_500k: batch 1 — replicate
        batch_axes = None
        cfg = cfg.replace(moe_shards=1)
    cfg = cfg.replace(act_spec=(batch_axes, None, "model"))
    if "gqarep" in opts:
        cfg = cfg.replace(attn_gqa_repeat=True)
    if "seqact" in opts:
        # §Perf: Megatron-SP-style activation sharding — shard the SEQUENCE
        # dim over the model axis between blocks instead of d_model. The
        # baseline (d→model) forces every weight-grad dot to all-gather its
        # activation over the model axis (the dW contraction needs full d);
        # sequence sharding keeps d intact so dW = xᵀdy reduces over the
        # data axis only (reduce-scatter), no giant gathers.
        cfg = cfg.replace(act_spec=(batch_axes, "model", None))
    if "noact" in opts:
        # §Perf: drop the per-block activation resharding constraint — kills
        # the per-layer all-gather/all-to-all pair at the cost of replicated
        # saved remat inputs (only safe for d_model ≤ ~8k archs).
        cfg = cfg.replace(act_spec=(batch_axes, None, None))
    if shape.kind == "train":
        cfg = cfg.replace(remat=True)
    if shape.name == "long_500k" and arch in LONG_SWA:
        pass  # window applied by make_decode_step(window_override=...)
    if shape.kind == "decode":
        # delta-cache serve contract: caches are read-only scan xs, deltas
        # are the tiny ys — safe to keep the layer scan.
        cfg = cfg.replace(attn_chunk=8192)
    if for_cost:
        cfg = cfg.replace(scan_layers=False, unroll_loops=True,
                          attn_chunk=4096 if shape.kind != "decode" else 16384,
                          ssm_chunk=2048, mlstm_chunk=2048)
    return cfg


def build_step(arch: str, shape: InputShape, mesh, *, for_cost: bool = False,
               num_layers: int | None = None, variant: str = "base"):
    """Returns (fn, args, in_shardings, meta) or None if skipped."""
    if shape.name == "long_500k" and arch in LONG_SKIP:
        return None
    opts = set(variant.split("+"))
    cfg = prepare_cfg(arch, shape, mesh, for_cost=for_cost, variant=variant)
    if num_layers is not None:
        cfg = cfg.replace(num_layers=num_layers)
    meta = {"arch": arch, "shape": shape.name, "kind": shape.kind,
            "variant": variant}

    if shape.kind == "train":
        plan = None
        if "nofl" not in opts:
            plan = ds.fli.make_fl_plan(
                num_clients=int(np.prod([mesh.shape[a] for a in mesh.axis_names
                                         if a != "model"])),
                num_clusters=4, key=jax.random.PRNGKey(0))
        import jax.numpy as _jnp
        kw = {}
        if "bf16accum" in opts:
            kw["accum_dtype"] = _jnp.bfloat16
        if "cechunk" in opts:
            kw["ce_mode"] = "resharded"
        fn, args, shardings = ds.make_train_step(cfg, shape, mesh, plan=plan,
                                                 **kw)
        meta["microbatches"] = ds.auto_microbatches(cfg, shape, mesh)
        return fn, args, shardings, None, meta
    if shape.kind == "prefill":
        fn, args, shardings, out_specs = ds.make_prefill_step(cfg, shape, mesh)
        return fn, args, shardings, out_specs, meta
    # decode
    ov = SWA_WINDOW if (shape.name == "long_500k" and arch in LONG_SWA) else None
    meta["window_override"] = ov
    fn, args, shardings = ds.make_decode_step(
        cfg, shape, mesh, window_override=ov,
        replicate_cache_heads="cacherep" in opts)
    return fn, args, shardings, None, meta


def run_one(arch: str, shape_name: str, mesh, mesh_name: str,
            variant: str = "base") -> dict:
    shape = INPUT_SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "variant": variant, "status": "skip"}
    t0 = time.time()
    try:
        built = build_step(arch, shape, mesh, variant=variant)
        if built is None:
            rec["reason"] = LONG_SKIP.get(arch, "n/a")
            return rec
        fn, args, shardings, out_specs, meta = built
        rec.update(meta)
        with mesh:
            jit_kw = {"in_shardings": ds.sr.named(shardings, mesh)}
            if out_specs is not None:
                jit_kw["out_shardings"] = ds.sr.named(out_specs, mesh)
            if shape.kind == "train":
                # params & opt_state are donated (updated in place on TPU)
                jit_kw["donate_argnums"] = (0, 1)
            # decode: caches are READ-ONLY (delta contract) — no donation
            lowered = jax.jit(fn, **jit_kw).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            ma = compiled.memory_analysis()
            ca = cost_analysis_dict(compiled)
            hlo = compiled.as_text()
            colls = parse_collectives(hlo)
        rec.update({
            "status": "ok",
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "mem": {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "peak_per_device": (ma.argument_size_in_bytes
                                    + ma.temp_size_in_bytes
                                    + ma.output_size_in_bytes
                                    - ma.alias_size_in_bytes),
            },
            "cost": {"flops": ca.get("flops", 0.0),
                     "bytes": ca.get("bytes accessed", 0.0)},
            "collectives": colls,
        })
    except Exception as e:  # noqa: BLE001 — dry-run reports failures
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"[:2000]
        rec["traceback"] = traceback.format_exc()[-4000:]
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod1", choices=["pod1", "pod2", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--variant", default="base")
    ap.add_argument("--out", default="results/dryrun.json")
    args = ap.parse_args()

    archs = ARCH_NAMES if (args.all or args.arch is None) else [args.arch]
    shapes = (list(INPUT_SHAPES) if (args.all or args.shape is None)
              else [args.shape])
    meshes = (["pod1", "pod2"] if args.mesh == "both" else [args.mesh])

    out_path = Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    results = []
    if out_path.exists():
        results = json.loads(out_path.read_text())
    done = {(r["arch"], r["shape"], r["mesh"], r.get("variant", "base"))
            for r in results if r["status"] in ("ok", "skip")}

    for mesh_name in meshes:
        mesh = make_production_mesh(multi_pod=(mesh_name == "pod2"))
        for arch in archs:
            for shape_name in shapes:
                key = (arch, shape_name, mesh_name, args.variant)
                if key in done:
                    continue
                print(f"[dryrun] {arch} × {shape_name} × {mesh_name} "
                      f"({args.variant}) ...", flush=True)
                rec = run_one(arch, shape_name, mesh, mesh_name,
                              variant=args.variant)
                print(f"  -> {rec['status']} "
                      f"mem/device={rec.get('mem', {}).get('peak_per_device', 0)/2**30:.2f} GiB "
                      f"compile={rec.get('compile_s', 0)}s "
                      f"{rec.get('error', '')}", flush=True)
                results = [r for r in results
                           if (r["arch"], r["shape"], r["mesh"],
                               r.get("variant", "base")) != key]
                results.append(rec)
                out_path.write_text(json.dumps(results, indent=1))
                jax.clear_caches()

    n_ok = sum(r["status"] == "ok" for r in results)
    n_fail = sum(r["status"] == "fail" for r in results)
    n_skip = sum(r["status"] == "skip" for r in results)
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skip, {n_fail} fail")
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
