"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
XLA_FLAGS before any jax initialization and then calls it.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod: (2, 16, 16) = 512 chips, axes (pod, data, model).

    Uses the first prod(shape) devices so a 512-device dry-run process can
    build both meshes."""
    import numpy as np
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devices)} — the dry-run must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax")
    arr = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(arr, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests: 8 fake CPU devices)."""
    if hasattr(jax.sharding, "AxisType"):  # jax >= 0.6 explicit-axes API
        return jax.make_mesh(
            (data, model), ("data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 2)
    return jax.make_mesh((data, model), ("data", "model"))


def _make_1d_mesh(axis: str, num_devices=None):
    n = len(jax.devices()) if num_devices is None else int(num_devices)
    if n < 1 or n > len(jax.devices()):
        raise ValueError(
            f"requested {n} devices for axis {axis!r}, have "
            f"{len(jax.devices())}")
    if hasattr(jax.sharding, "AxisType"):  # jax >= 0.6 explicit-axes API
        return jax.make_mesh((n,), (axis,),
                             axis_types=(jax.sharding.AxisType.Auto,))
    return jax.make_mesh((n,), (axis,))


def make_mc_mesh(num_devices=None):
    """Monte-Carlo trajectory mesh: 1-D, axis ``("mc",)``, over all devices
    by default.  `repro.sim.sharded` shards the flattened seeds × SNR
    trajectory grid along ``mc`` — the embarrassingly parallel axis of a
    scenario sweep — with `repro.dist.sharding_rules.trajectory_specs`
    fitting the leading trajectory dim to this mesh."""
    return _make_1d_mesh("mc", num_devices)


def make_client_mesh(num_devices=None):
    """Client-parallel mesh: 1-D, axis ``("clients",)``.  Used by
    `repro.sim.sharded.run_rounds_client_sharded` to split the stacked
    K-client axis of one large-K trajectory across devices (K must divide
    by the axis size; `sharding_rules.client_specs` fits the specs)."""
    return _make_1d_mesh("clients", num_devices)


def fsdp_axes(mesh) -> tuple:
    """The axes used for fully-sharded parameter dims (pod joins FSDP)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def batch_axes(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
