"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
XLA_FLAGS before any jax initialization and then calls it.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod: (2, 16, 16) = 512 chips, axes (pod, data, model).

    Uses the first prod(shape) devices so a 512-device dry-run process can
    build both meshes."""
    import numpy as np
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devices)} — the dry-run must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax")
    arr = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(arr, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests: 8 fake CPU devices)."""
    if hasattr(jax.sharding, "AxisType"):  # jax >= 0.6 explicit-axes API
        return jax.make_mesh(
            (data, model), ("data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 2)
    return jax.make_mesh((data, model), ("data", "model"))


def fsdp_axes(mesh) -> tuple:
    """The axes used for fully-sharded parameter dims (pod joins FSDP)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def batch_axes(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
