"""Generate EXPERIMENTS.md §Dry-run and §Roofline tables from the JSON
results produced by repro.launch.dryrun / repro.launch.roofline.

    PYTHONPATH=src python -m repro.launch.report \
        [--dryrun-json PATH] [--roofline-json PATH]

prints markdown to stdout (paste/refresh into EXPERIMENTS.md).  Paths
default to the ``results/*.json`` layout the launch tools write, but are
arguments — CI jobs and ad-hoc runs keep their results wherever they
like.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

GIB = 2 ** 30


def dryrun_table(path="results/dryrun.json") -> str:
    if not Path(path).exists():
        return "_dry-run results not yet generated_"
    rows = json.loads(Path(path).read_text())
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    out = ["| arch | shape | mesh | status | peak GiB/dev | compile s | M | top collectives (per scan iter) |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        mem = r.get("mem", {}).get("peak_per_device", 0) / GIB
        colls = r.get("collectives", {})
        top = ", ".join(
            f"{k}×{v['count']} ({v['bytes']/GIB:.2f}G)"
            for k, v in sorted(colls.items(),
                               key=lambda kv: -kv[1]["bytes"])[:2])
        status = r["status"]
        if status == "skip":
            top = r.get("reason", "")
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {status} | "
            f"{mem:.2f} | {r.get('compile_s', '')} | "
            f"{r.get('microbatches', '')} | {top} |")
    n_ok = sum(r["status"] == "ok" for r in rows)
    n_fail = sum(r["status"] == "fail" for r in rows)
    n_skip = sum(r["status"] == "skip" for r in rows)
    out.append(f"\n**{n_ok} ok / {n_skip} skip / {n_fail} fail** "
               f"out of {len(rows)} (arch × shape × mesh) combinations.")
    return "\n".join(out)


def roofline_table(path="results/roofline.json") -> str:
    if not Path(path).exists():
        return "_roofline results not yet generated_"
    rows = json.loads(Path(path).read_text())
    rows.sort(key=lambda r: (r["arch"], r["shape"],
                             r.get("variant", "base") != "base",
                             r.get("variant", "base")))
    out = ["| arch | shape | variant | compute s | memory s | collective s | dominant | MODEL_FLOPS | useful ratio | M |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        v = r.get("variant", "base")
        if r["status"] == "skip":
            out.append(f"| {r['arch']} | {r['shape']} | {v} | — | — | — | "
                       f"skip: {r.get('reason','')[:40]} | — | — | — |")
            continue
        if r["status"] == "fail":
            out.append(f"| {r['arch']} | {r['shape']} | {v} | — | — | — | "
                       f"FAIL | — | — | — |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {v} | "
            f"{r['t_compute_s']*1e3:.1f}ms | "
            f"{r['t_memory_s']*1e3:.1f}ms | {r['t_collective_s']*1e3:.1f}ms | "
            f"**{r['dominant']}** | {r['model_flops']:.2e} | "
            f"{r['useful_ratio']:.2f} | {r.get('microbatches','')} |")
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dryrun-json", default="results/dryrun.json",
                    help="dry-run results JSON (repro.launch.dryrun)")
    ap.add_argument("--roofline-json", default="results/roofline.json",
                    help="roofline results JSON (repro.launch.roofline)")
    args = ap.parse_args(argv)
    print("## §Dry-run\n")
    print(dryrun_table(args.dryrun_json))
    print("\n## §Roofline\n")
    print(roofline_table(args.roofline_json))


if __name__ == "__main__":
    main()
