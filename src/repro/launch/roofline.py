import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Roofline analysis (deliverable g): derive the three roofline terms for
every (arch × input-shape) on the single-pod production mesh.

Methodology (see EXPERIMENTS.md §Roofline):

* XLA's HloCostAnalysis counts a while-loop body ONCE (scan trip counts are
  invisible), and the CPU backend hides matmul flops inside oneDNN
  custom-calls. We therefore measure UNROLLED lowerings (python-loop layers,
  unrolled attention/SSM chunk loops) of 1-period and 2-period variants and
  extrapolate linearly:
      per_period = m(2) − m(1);   total = m(1) + (num_periods − 1)·per_period
  `lowered.cost_analysis()` (pre-optimization, GLOBAL across devices) gives
  flops and bytes; the compiled per-device HLO gives the collective traffic.
* Collective traffic applies ring-algorithm factors: all-reduce 2×(n−1)/n,
  all-gather/reduce-scatter (n−1)/n, all-to-all (n−1)/n, permute 1×.
* sLSTM layers are an elementwise time-scan (cannot be unrolled at 32k) —
  their flops are added analytically (noted per row).

Terms (seconds, TPU v5e):
  compute    = FLOPs_global / (chips · 197 TFLOP/s)
  memory     = bytes_global / (chips · 819 GB/s)
  collective = collective_bytes_per_device / 50 GB/s
"""
import argparse
import json
import math
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_config
from repro.launch.dryrun import (LONG_SKIP, LONG_SWA, SWA_WINDOW,
                                 parse_collectives, prepare_cfg)
from repro.launch.mesh import make_production_mesh
from repro.models.config import INPUT_SHAPES, InputShape
from repro.models.transformer import count_active_params, count_params
from repro.training import dist_steps as ds

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # B/s / chip
LINK_BW = 50e9             # B/s / link
CHIPS = 256

RING_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}


def _measure(arch: str, shape: InputShape, mesh, periods: int,
             variant: str = "base") -> dict:
    """Lower+compile an unrolled ``periods``-period variant; return global
    flops/bytes and per-device weighted collective bytes."""
    opts = set(variant.split("+"))
    cfg = prepare_cfg(arch, shape, mesh, for_cost=True, variant=variant)
    cfg = cfg.replace(num_layers=periods * len(cfg.pattern))

    if shape.kind == "train":
        # microbatches=1: the grad-accumulation scan hides (M−1)/M of the
        # flops from cost analysis; the roofline is per full batch with a
        # single accumulation (real M reported per row; per-microbatch grad
        # reductions scale the collective term by ~M in deployment).
        kw = {}
        if "bf16accum" in opts:
            kw["accum_dtype"] = jnp.bfloat16
        if "cechunk" in opts:
            kw["ce_mode"] = "resharded"
        fn, args, shardings = ds.make_train_step(cfg, shape, mesh, plan=None,
                                                 microbatches=1, **kw)
        out_specs = None
    elif shape.kind == "prefill":
        fn, args, shardings, out_specs = ds.make_prefill_step(cfg, shape,
                                                              mesh)
    else:
        ov = SWA_WINDOW if (shape.name == "long_500k" and arch in LONG_SWA) \
            else None
        fn, args, shardings = ds.make_decode_step(
            cfg, shape, mesh, window_override=ov,
            replicate_cache_heads="cacherep" in opts)
        out_specs = None

    with mesh:
        kw = {"in_shardings": ds.sr.named(shardings, mesh)}
        if out_specs is not None:
            kw["out_shardings"] = ds.sr.named(out_specs, mesh)
        lowered = jax.jit(fn, **kw).lower(*args)
        ca = lowered.cost_analysis()           # GLOBAL flops (pre-partition)
        compiled = lowered.compile()
        colls = parse_collectives(compiled.as_text())
    coll_bytes = sum(RING_FACTOR.get(k, 1.0) * v["bytes"]
                     for k, v in colls.items())
    return {"flops": float(ca.get("flops", 0.0)),
            "coll_bytes": float(coll_bytes),
            "colls": colls,
            "microbatches": (ds.auto_microbatches(cfg, shape, mesh)
                             if shape.kind == "train" else 1)}


def analytic_hbm_bytes(arch: str, shape: InputShape) -> float:
    """Analytic per-device HBM traffic model (bytes). XLA-CPU's measured
    'bytes accessed' reflects CPU fusion, not TPU HBM traffic, so the memory
    term uses the standard napkin model:

      params: read every pass (train: fwd+bwd+update r/w ≈ 4×; else 1×),
      activations: ~12 (tokens_local × d) r/w per layer (×3 for train),
      decode: + full KV-cache/state read per step.
    """
    cfg = get_config(arch)
    n_params = count_params(cfg)
    p_bytes = 2.0 * n_params / CHIPS           # bf16, fully sharded
    passes = 4.0 if shape.kind == "train" else 1.0
    tokens_local = (shape.global_batch * shape.seq_len
                    if shape.kind != "decode" else shape.global_batch)
    tokens_local /= min(CHIPS, 16)             # data-sharded (16-way)
    act_mult = 3.0 if shape.kind == "train" else 1.0
    act = 12.0 * cfg.num_layers * tokens_local * cfg.d_model * 2 * act_mult
    act /= 16.0                                # activations model-sharded
    cache = 0.0
    if shape.kind == "decode":
        # full cache read per decode step, sharded over 256 chips
        per_layer = {"attn": 2 * shape.seq_len * cfg.num_kv_heads * cfg.hd,
                     "mamba": cfg.d_inner * (cfg.ssm_state + cfg.ssm_conv),
                     "mlstm": (2 * cfg.d_model / max(cfg.num_heads, 1)) ** 2
                              * cfg.num_heads,
                     "slstm": 4 * cfg.d_model}
        for s in cfg.pattern:
            w = per_layer.get(s.mixer, 0.0)
            if s.mixer == "attn" and s.window:
                w = 2 * min(shape.seq_len, s.window) * cfg.num_kv_heads * cfg.hd
            cache += w * cfg.num_periods * shape.global_batch * 2
        cache /= CHIPS
    return p_bytes + act + cache


def _slstm_flops(cfg, shape) -> float:
    """Analytic flops of sLSTM layers (time-scan, invisible to unrolling)."""
    n_slstm = sum(1 for s in cfg.pattern if s.mixer == "slstm")
    n_slstm *= cfg.num_periods
    if n_slstm == 0:
        return 0.0
    d = cfg.d_model
    dh = d // cfg.num_heads
    tokens = (shape.global_batch * shape.seq_len if shape.kind != "decode"
              else shape.global_batch)
    per_tok = 2 * 4 * d * dh + 40 * d      # 4 recurrent matvecs + gates
    mult = 3.0 if shape.kind == "train" else 1.0   # fwd+bwd
    return n_slstm * tokens * per_tok * mult


def model_flops(arch: str, shape: InputShape) -> float:
    cfg = get_config(arch)
    n_active = count_active_params(cfg)
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch          # decode: 1 token


def analyse(arch: str, shape_name: str, mesh, variant: str = "base") -> dict:
    shape = INPUT_SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "variant": variant,
           "status": "skip"}
    if shape.name == "long_500k" and arch in LONG_SKIP:
        rec["reason"] = LONG_SKIP[arch]
        return rec
    try:
        t0 = time.time()
        m1 = _measure(arch, shape, mesh, periods=1, variant=variant)
        jax.clear_caches()
        m2 = _measure(arch, shape, mesh, periods=2, variant=variant)
        jax.clear_caches()
        cfg = get_config(arch)
        P = cfg.num_periods

        def total(key):
            per = m2[key] - m1[key]
            return m1[key] + (P - 1) * per

        flops = total("flops") + _slstm_flops(
            prepare_cfg(arch, shape, mesh, for_cost=True), shape)
        bytes_ = analytic_hbm_bytes(arch, shape)    # per-device (see docstring)
        coll = total("coll_bytes")

        t_compute = flops / (CHIPS * PEAK_FLOPS)
        t_memory = bytes_ / HBM_BW
        t_coll = coll / LINK_BW
        terms = {"compute": t_compute, "memory": t_memory,
                 "collective": t_coll}
        dominant = max(terms, key=terms.get)
        mf = model_flops(arch, shape)
        rec.update({
            "status": "ok",
            "flops_global": flops,
            "hbm_bytes_per_device": bytes_,
            "coll_bytes_per_device": coll,
            "collectives_1p": m1["colls"],
            "t_compute_s": t_compute,
            "t_memory_s": t_memory,
            "t_collective_s": t_coll,
            "dominant": dominant,
            "model_flops": mf,
            "useful_ratio": mf / flops if flops else 0.0,
            "microbatches": m1["microbatches"],
            "measure_s": round(time.time() - t0, 1),
        })
    except Exception as e:  # noqa: BLE001
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"[:1500]
        rec["traceback"] = traceback.format_exc()[-3000:]
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--variant", default="base")
    ap.add_argument("--out", default="results/roofline.json")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ARCH_NAMES
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    mesh = make_production_mesh()

    out_path = Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    results = json.loads(out_path.read_text()) if out_path.exists() else []
    done = {(r["arch"], r["shape"], r.get("variant", "base"))
            for r in results if r["status"] in ("ok", "skip")}

    for arch in archs:
        for shape_name in shapes:
            if (arch, shape_name, args.variant) in done:
                continue
            print(f"[roofline] {arch} × {shape_name} ({args.variant}) ...",
                  flush=True)
            rec = analyse(arch, shape_name, mesh, variant=args.variant)
            if rec["status"] == "ok":
                print(f"  -> {rec['dominant']}-bound  "
                      f"c={rec['t_compute_s']*1e3:.1f}ms "
                      f"m={rec['t_memory_s']*1e3:.1f}ms "
                      f"n={rec['t_collective_s']*1e3:.1f}ms "
                      f"useful={rec['useful_ratio']:.2f}", flush=True)
            else:
                print(f"  -> {rec['status']} {rec.get('error','')[:200]}",
                      flush=True)
            results = [r for r in results
                       if (r["arch"], r["shape"], r.get("variant", "base"))
                       != (arch, shape_name, args.variant)]
            results.append(rec)
            out_path.write_text(json.dumps(results, indent=1))


if __name__ == "__main__":
    main()
