"""``repro.dist`` — the OTA collective substrate for the production mesh.

Three layers, mirroring the paper's offline/online split:

* :mod:`repro.dist.sharding_rules` — mesh-shape-aware PartitionSpec
  inference (FSDP/BATCH axis aliases, divisibility-fitted specs) for every
  parameter/batch/cache leaf of the assigned architectures.
* :mod:`repro.dist.fl_integration` — the offline FL plan (clustering,
  water-filled β, channel-noise budget) and the paper-faithful hierarchical
  OTA all-reduce usable inside ``jax.shard_map`` over the ``data`` axis.
* :mod:`repro.dist.ota_collectives` — flat-vector lowerings of the CWFL
  aggregation that reuse :mod:`repro.core.channel` math verbatim and route
  the phase-1 MAC through the Pallas ``ota_aggregate`` kernel when shapes
  allow.
"""
from __future__ import annotations

import jax

# ``jax.shard_map`` graduated from ``jax.experimental.shard_map`` in newer
# jax releases; export a version-agnostic binding here (without mutating
# the jax namespace) and spell it ``repro.dist.shard_map`` everywhere.
if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map  # noqa: F401

from repro.dist import fl_integration, ota_collectives, sharding_rules  # noqa: E402,F401
from repro.dist.fl_integration import (FLPlan, hierarchical_ota_allreduce,  # noqa: E402,F401
                                       make_fl_plan)
