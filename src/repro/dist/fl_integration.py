"""CWFL ⇄ production-training integration: the offline FL plan and the
paper-faithful hierarchical OTA collective.

Shard mode (DESIGN.md §3): one sharded model copy; clients are groups of
examples in the global batch.  Because per-example losses enter the total
loss linearly, the gradient of the β-weighted mean loss equals the
β-weighted consensus of per-client gradients — so CWFL's Algorithm 1
reduces to (a) per-example loss weights ``example_weights`` and (b) a
post-backward channel-noise injection ``add_channel_noise`` whose std is
the consensus-noise budget of the two-phase collective.

Replica / mesh-collective mode: ``hierarchical_ota_allreduce`` runs the
two OTA phases literally inside ``jax.shard_map`` over the ``data`` axis —
phase 1 is an intra-cluster OTA MAC (a masked, amplitude-weighted ``psum``),
phase 2 the inter-head consensus mix — returning the receiver-independent
consensus mean on every client rank.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cwfl
from repro.core.cwfl import CWFLState
from repro.core.topology import TopologyConfig, make_topology
from repro.utils import tree_add_noise


@dataclasses.dataclass(frozen=True)
class FLPlan:
    """Everything the training step needs from the offline FL phase.

    ``beta`` is the water-filling-derived client distribution (Σβ = 1):
    the *effective* weight of client k's signal in the collective's
    consensus output, β_k = Σ_c colmean(B)_c · Ã_{c,k}, where Ã is the
    row-normalized phase-1 amplitude matrix (sqrt(P_k/P) for members, 1
    for heads) and B the normalized consensus mix — so shard mode's
    weighted loss optimizes the same weighted objective the hierarchical
    collective aggregates.  ``noise_std`` is the std of the
    consensus-mean channel noise per sync (the Q₂ term of Theorem 1);
    ``phase1_rel_std`` / ``phase2_rel_std`` are the per-cluster per-phase
    noise stds *per unit* ``noise_std`` so that rescaling (or zeroing)
    ``noise_std`` rescales the whole collective consistently.
    """

    num_clients: int
    num_clusters: int
    beta: np.ndarray              # (K,) water-filled client weights, Σ = 1
    assignment: np.ndarray        # (K,) cluster id per client
    heads: np.ndarray             # (C,) head client index per cluster
    mix: np.ndarray               # (C, C) inter-head weights W (diag = 0)
    cluster_weights: np.ndarray   # (C, C) row-normalized (W + I)
    noise_std: float              # consensus-mean channel noise std
    phase1_rel_std: np.ndarray    # (C,) θ̃ noise std / noise_std
    phase2_rel_std: np.ndarray    # (C,) head-exchange noise std / noise_std
    snr_db: float
    state: CWFLState              # full Algorithm-1 state (replica mode)

    def client_of_example(self, n: int) -> np.ndarray:
        """(n,) client id per example: contiguous, balanced blocks."""
        return (np.arange(n) * self.num_clients) // n

    def example_weights(self, n: int) -> np.ndarray:
        """(n,) loss weights with mean 1 implementing the weighted-loss ⇔
        explicit-consensus equivalence (DESIGN.md §3): the gradient of
        mean(w · per-example-loss) equals Σ_k β_k ∇ mean_k(loss).

        If the batch is smaller than the client count, β is renormalized
        over the clients actually present so the mean-1 invariant (and
        the equivalence, restricted to present clients) still holds; if
        every present client has zero water-filled β, the weights fall
        back to uniform rather than silently zeroing the gradient."""
        c = self.client_of_example(n)
        counts = np.bincount(c, minlength=self.num_clients)
        beta = self.beta
        if n < self.num_clients:
            present = counts > 0
            mass = beta[present].sum()
            if mass <= 0.0:
                return np.ones((n,), beta.dtype)
            beta = beta * present / mass
        return n * beta[c] / counts[c]


def make_fl_plan(num_clients: int, num_clusters: int, key: jax.Array,
                 snr_db: float = 40.0) -> FLPlan:
    """Offline phase: draw a topology, cluster on SNR, water-fill power,
    and precompute the consensus-noise budget for the online collective."""
    k_topo, k_setup = jax.random.split(key)
    topo = make_topology(
        k_topo, TopologyConfig(num_clients=num_clients,
                               num_hotspots=max(min(num_clusters,
                                                    num_clients), 1)))
    # K-means may leave clusters empty for small K (all clients at one
    # hotspot); an empty cluster has a zero phase-1 row whose receiver
    # renormalization explodes the noise budget. Retry with the achieved
    # number of non-empty clusters until every cluster has members.
    c_req = max(min(num_clusters, num_clients), 1)
    while True:
        state = cwfl.setup(
            topo, cwfl.CWFLConfig(num_clusters=c_req, snr_db=snr_db),
            k_setup)
        sizes = np.bincount(np.asarray(state.plan.assignment),
                            minlength=c_req)
        if c_req == 1 or (sizes > 0).all():
            break
        c_req = max(int((sizes > 0).sum()), 1)

    # Phase-1 effective noise after receiver scaling + row normalization
    # (same renormalization as cwfl.aggregate with normalize=True).  Uses
    # the state's per-cluster receiver stds rather than re-deriving from
    # snr_db, so the budget tracks whatever setup() assigned.
    A = np.asarray(cwfl.phase1_weights(state), np.float64)
    row_a = np.maximum(A.sum(axis=1), 1e-12)
    a_norm = A / row_a[:, None]
    s1 = (np.asarray(state.head_noise_std, np.float64)
          / np.sqrt(state.total_power) / row_a)                # (C,)

    b_norm_j, s2_j = cwfl.phase2_weights(state)
    b_norm = np.asarray(b_norm_j, np.float64)
    s2 = np.asarray(s2_j, np.float64)                          # (C,)
    C = b_norm.shape[0]

    # Effective per-client consensus weight of the collective (see FLPlan
    # docstring) — shard mode weights losses with exactly these.
    col_mean = b_norm.mean(axis=0)
    beta = col_mean @ a_norm
    beta = beta / max(beta.sum(), 1e-12)

    # Std of the consensus mean: the phase-1 noise of cluster j reaches the
    # mean with coefficient colmean(b_norm)_j; phase-2 noise averages 1/C.
    var = float((col_mean ** 2 * s1 ** 2).sum() + (s2 ** 2).sum() / C ** 2)
    noise_std = float(np.sqrt(var))
    denom = max(noise_std, 1e-30)

    return FLPlan(
        num_clients=num_clients,
        num_clusters=C,
        beta=beta,
        assignment=np.asarray(state.plan.assignment),
        heads=np.asarray(state.plan.heads),
        mix=np.asarray(state.mix),
        cluster_weights=b_norm,
        noise_std=noise_std,
        phase1_rel_std=s1 / denom,
        phase2_rel_std=s2 / denom,
        snr_db=float(snr_db),
        state=state,
    )


def add_channel_noise(grads, key: jax.Array, noise_std):
    """Post-backward channel-noise injection (shard mode).  A static zero
    std is a no-op so the noiseless path adds no PRNG work to the HLO."""
    if isinstance(noise_std, (int, float)) and noise_std <= 0.0:
        return grads
    return tree_add_noise(grads, key, noise_std)


def hierarchical_ota_allreduce(x: jax.Array, plan: FLPlan, key: jax.Array,
                               axis_name: str = "data") -> jax.Array:
    """The paper-faithful two-phase collective, inside ``jax.shard_map``.

    Each rank along ``axis_name`` is one client (axis size must equal
    ``plan.num_clients``); ``x`` is that client's local value (any shape).

    Phase 1 (eq. 8): every cluster-head receives the OTA superposition of
    its members' amplitude-weighted signals — a masked ``psum`` with the
    row-normalized phase-1 weights — plus receiver AWGN.
    Phase 2 (eq. 9 / lemma 2): heads exchange θ̃ and mix with the
    row-normalized SNR weights, plus per-link AWGN.
    Phase 3: error-free broadcast.  The receiver-independent consensus mean
    is returned identically on every rank (noise keys are shared, so all
    ranks see the same channel realization — the broadcast equality of the
    paper holds exactly).
    """
    axis_size = jax.lax.psum(1, axis_name)
    if isinstance(axis_size, int) and axis_size != plan.num_clients:
        # the per-rank weight-column lookup below clamps out-of-range
        # indices — a silent wrong answer without this check.
        raise ValueError(
            f"plan has {plan.num_clients} clients but axis "
            f"{axis_name!r} has {axis_size} ranks; one client per rank")

    a = jnp.asarray(cwfl.phase1_weights(plan.state), jnp.float32)
    a = a / jnp.maximum(a.sum(axis=1, keepdims=True), 1e-12)
    b_norm = jnp.asarray(plan.cluster_weights, jnp.float32)
    c = a.shape[0]

    k = jax.lax.axis_index(axis_name)
    col = jax.lax.dynamic_index_in_dim(a, k, axis=1, keepdims=False)  # (C,)
    xf = x.astype(jnp.float32)
    contrib = col.reshape((c,) + (1,) * xf.ndim) * xf[None]

    # Phase 1: OTA MAC — the superposition over clients IS the psum.
    theta_tilde = jax.lax.psum(contrib, axis_name)            # (C,) + x.shape
    k1, k2 = jax.random.split(key)
    std1 = plan.noise_std * jnp.asarray(plan.phase1_rel_std, jnp.float32)
    theta_tilde = theta_tilde + std1.reshape(
        (c,) + (1,) * xf.ndim) * jax.random.normal(k1, theta_tilde.shape,
                                                   jnp.float32)

    # Phase 2: inter-head consensus mix + equivalent per-receiver noise.
    theta_bar = jnp.tensordot(b_norm, theta_tilde, axes=1)
    std2 = plan.noise_std * jnp.asarray(plan.phase2_rel_std, jnp.float32)
    theta_bar = theta_bar + std2.reshape(
        (c,) + (1,) * xf.ndim) * jax.random.normal(k2, theta_bar.shape,
                                                   jnp.float32)

    # Phase 3: error-free broadcast of the consensus mean.
    return jnp.mean(theta_bar, axis=0).astype(x.dtype)
