"""Mesh-shape-aware PartitionSpec inference for the production mesh.

The production meshes are (data, model) = (16, 16) or (pod, data, model) =
(2, 16, 16); tests run on small fake meshes.  Rather than hand-writing a
spec per parameter per mesh, every rule here is *fitted* to the mesh shape:

* ``FSDP`` / ``BATCH`` are axis **aliases** that expand to the fully-sharded
  axis group of the current mesh (``("pod", "data")`` when a pod axis
  exists, else ``("data",)``).
* ``_fit_dim`` drops leading axes (pod first) until the remaining axis
  group's size divides the dimension — a dim that nothing divides stays
  replicated instead of erroring.
* ``fit_spec`` additionally guarantees an axis is never reused across dims
  of one leaf (XLA rejects duplicate mesh axes in a PartitionSpec).

``param_specs`` / ``batch_specs`` / ``cache_specs`` apply these rules to
every leaf of the model parameter / input-batch / decode-cache pytrees; the
coverage across all assigned architectures is pinned by
``tests/test_sharding_rules.py``.  ``trajectory_specs`` / ``client_specs``
fit the scenario subsystem's meshes the same way — the Monte-Carlo
trajectory axis (``mc``) and the stacked FL client axis (``clients``)
of `repro.sim.sharded` (pinned by ``tests/test_sim_sharded.py``).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

# Axis aliases, resolved against the mesh at fit time.
FSDP = "__fsdp__"     # fully-sharded parameter dim: ("pod", "data")
BATCH = "__batch__"   # data-parallel batch dim:     ("pod", "data")
MC = "__mc__"         # Monte-Carlo trajectory dim:  ("mc",)
CLIENTS = "__clients__"  # stacked FL client dim:    ("clients",)

_ALIAS_AXES = ("pod", "data")


def _mesh_sizes(mesh) -> dict:
    return dict(mesh.shape)


def _axes_for(entry, mesh):
    """Expand a spec entry (None / name / tuple / alias) to mesh axes."""
    if entry is None:
        return ()
    if entry in (FSDP, BATCH):
        cand = _ALIAS_AXES
    elif entry == MC:
        cand = ("mc",)
    elif entry == CLIENTS:
        cand = ("clients",)
    elif isinstance(entry, tuple):
        cand = entry
    else:
        cand = (entry,)
    return tuple(a for a in cand if a in mesh.axis_names)


def _fit_dim(dim: int, axes: tuple, mesh):
    """Largest suffix of ``axes`` whose total mesh size divides ``dim``.

    Leading axes are dropped first — for the FSDP group ``("pod", "data")``
    this drops ``pod`` before giving up on sharding entirely.  Returns a
    bare axis name, a tuple of names, or None (replicate).
    """
    sizes = _mesh_sizes(mesh)
    axes = tuple(axes)
    while axes:
        total = int(np.prod([sizes[a] for a in axes]))
        if total > 0 and dim % total == 0:
            return axes if len(axes) > 1 else axes[0]
        axes = axes[1:]
    return None


def fit_spec(shape: tuple, want: tuple, mesh) -> P:
    """Fit the requested per-dim axes to ``shape`` on ``mesh``.

    ``want`` entries may be None, an axis name, a tuple of names, or the
    FSDP/BATCH aliases; missing trailing entries default to None.  An axis
    already consumed by an earlier dim is never reused.
    """
    want = tuple(want) + (None,) * (len(shape) - len(want))
    used: set = set()
    parts = []
    for dim, entry in zip(shape, want):
        axes = tuple(a for a in _axes_for(entry, mesh) if a not in used)
        fitted = _fit_dim(dim, axes, mesh) if axes else None
        if fitted is not None:
            used.update(fitted if isinstance(fitted, tuple) else (fitted,))
        parts.append(fitted)
    return P(*parts)


# ---------------------------------------------------------------------------
# Pytree-level rules.
# ---------------------------------------------------------------------------

def _path_keys(path) -> tuple:
    return tuple(str(getattr(p, "key", getattr(p, "name", p))) for p in path)


def _param_want(keys: tuple, shape: tuple) -> tuple:
    """Per-leaf sharding intent, before mesh fitting.

    * ``embed`` — vocab over ``model``, d_model over FSDP (the transpose of
      a plain matmul weight: the vocab dim is the huge one and the embedding
      gather is model-axis local).
    * MoE expert stacks ``(E, d_in, d_out)`` — expert-parallel: E over the
      FSDP/data group, output features over ``model``.
    * any other matrix — input features over FSDP, output features over
      ``model`` (Megatron layout).
    * vectors/scalars — replicated.

    Leaves under a ``layers`` stack carry a leading period axis that is
    always replicated (it is scanned, not sharded).
    """
    name = keys[-1]
    stacked = "layers" in keys[:-1]
    core = shape[1:] if stacked else shape
    if name == "embed":
        want: tuple = ("model", FSDP)
    elif "moe" in keys and len(core) == 3:
        want = (FSDP, None, "model")
    elif len(core) >= 2:
        want = (None,) * (len(core) - 2) + (FSDP, "model")
    else:
        want = (None,) * len(core)
    return ((None,) + want) if stacked else want


def param_specs(p_shapes, mesh):
    """PartitionSpec pytree covering every parameter leaf."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(p_shapes)
    specs = [fit_spec(leaf.shape, _param_want(_path_keys(path), leaf.shape),
                      mesh)
             for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_specs(batch_shapes, mesh):
    """Batch leaves: leading (global-batch) dim over the BATCH group, rest
    replicated.  A batch of 1 (long-context serving) stays replicated via
    the divisibility fit."""
    return jax.tree.map(
        lambda s: fit_spec(s.shape, (BATCH,) + (None,) * (len(s.shape) - 1),
                           mesh),
        batch_shapes)


def cache_specs(cache_shapes, mesh):
    """Decode/prefill cache leaves ``(periods, B, ..., head_dim)``: batch
    over the BATCH group, trailing feature dim over ``model`` (KV head_dim
    for attention caches), everything else replicated."""
    def one(s):
        n = len(s.shape)
        if n >= 4:
            want = (None, BATCH) + (None,) * (n - 3) + ("model",)
        else:
            want = (None, BATCH) + (None,) * max(n - 2, 0)
        return fit_spec(s.shape, want[:n], mesh)
    return jax.tree.map(one, cache_shapes)


def trajectory_specs(shapes, mesh):
    """Monte-Carlo sweep leaves ``(N_traj, ...)``: the leading (flattened
    seeds × SNR) trajectory dim over the ``mc`` axis, rest replicated.
    `repro.sim.sharded` pads N_traj to the axis size before fitting, so
    the divisibility rule never silently replicates a sweep."""
    return jax.tree.map(
        lambda s: fit_spec(s.shape, (MC,) + (None,) * (len(s.shape) - 1),
                           mesh),
        shapes)


def client_specs(shapes, mesh):
    """Stacked-client FL leaves ``(K, ...)``: the leading client dim over
    the ``clients`` axis, rest replicated (one shard = K/n clients)."""
    return jax.tree.map(
        lambda s: fit_spec(s.shape,
                           (CLIENTS,) + (None,) * (len(s.shape) - 1), mesh),
        shapes)


def named(specs, mesh):
    """PartitionSpec pytree -> NamedSharding pytree (jit in_shardings)."""
    return jax.tree.map(lambda p: NamedSharding(mesh, p), specs,
                        is_leaf=lambda x: isinstance(x, P))
