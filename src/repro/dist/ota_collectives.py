"""Flat-vector / shard_map lowerings of the CWFL aggregation.

Two entry families:

* ``phase1_ota_flat`` / ``cwfl_aggregate_flat`` — Algorithm 1 on a flat
  ``(K, d)`` client-signal matrix.  The channel math (eq. 5 precoding,
  eq. 8 receiver scaling, lemma-2 noise) is the *same code* the reference
  operator :func:`repro.core.cwfl.aggregate` uses; the full sync round —
  OTA MAC → consensus mix → broadcast over the d-dimensional flattened
  parameters, the per-round hot spot — is routed through the fused
  single-pass Pallas kernel :func:`repro.kernels.cwfl_round.cwfl_round`
  when the vector is large enough to benefit (``d >= PALLAS_MIN_DIM``),
  keeping the intermediate θ̃/θ̄ states out of HBM entirely.
* ``ota_allreduce_tree`` / ``build_gradient_allreduce`` — the device
  collective: the hierarchical two-phase OTA all-reduce applied to
  gradient/parameter pytrees across the mesh's ``data`` axis (one client
  per data rank), either inside an existing ``jax.shard_map`` body or as a
  standalone jitted collective.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import cwfl
from repro.core.cwfl import CWFLState
from repro.dist import shard_map
from repro.dist.fl_integration import FLPlan, hierarchical_ota_allreduce
from repro.kernels.cwfl_round import PALLAS_MIN_DIM, cwfl_round_auto
from repro.kernels.ota_aggregate import DEFAULT_TILE
from repro.kernels.ota_aggregate import ota_aggregate as _pallas_ota
from repro.kernels.ref import ota_aggregate_ref
from repro.utils import tree_flatten_vector, tree_unflatten_vector


def phase1_ota_flat(signals: jnp.ndarray, state: CWFLState, key: jax.Array,
                    *, normalize: bool = True, precode: bool = True,
                    tile: int = DEFAULT_TILE,
                    interpret: Optional[bool] = None,
                    use_pallas: Optional[bool] = None) -> jnp.ndarray:
    """Phase-1 OTA MAC on flat vectors: ``(K, d) -> (C, d)`` (eq. 8).

    Matches :func:`repro.core.cwfl.aggregate`'s phase 1 leaf-for-leaf when
    the pytree is flattened to one vector per client.  ``interpret``
    defaults to the Pallas interpreter off-TPU (CPU validation) and the
    compiled kernel on TPU.
    """
    _, d = signals.shape
    sig32 = signals.astype(jnp.float32)
    # a flat (K, d) matrix is itself a K-stacked pytree, so the reference
    # operator's weight math applies verbatim (no twin copy to drift).
    a, eff_std, _, _, _ = cwfl.round_coefficients(
        state, sig32, normalize, precode)
    noise = eff_std[:, None] * jax.random.normal(
        key, (a.shape[0], d), jnp.float32)
    if use_pallas is None:
        use_pallas = d >= PALLAS_MIN_DIM
    if use_pallas:
        return _pallas_ota(sig32, a, noise, tile=tile, interpret=interpret)
    return ota_aggregate_ref(sig32, a, noise)


def cwfl_aggregate_flat(signals: jnp.ndarray, state: CWFLState,
                        key: jax.Array, *, normalize: bool = True,
                        precode: bool = True, tile: int = DEFAULT_TILE,
                        interpret: Optional[bool] = None,
                        use_pallas: Optional[bool] = None):
    """Full Algorithm 1 on a flat ``(K, d)`` matrix, single-pass fused.

    Returns ``(new_signals (K, d), consensus (d,))`` — the flat-vector twin
    of :func:`repro.core.cwfl.aggregate` (exactly equal in the noiseless
    case; noise keys are split differently per leaf in the pytree path).
    Above ``PALLAS_MIN_DIM`` the whole round (MAC, consensus mix,
    broadcast, consensus mean) runs in one Pallas pass per d-tile; below,
    the jnp three-matmul reference.
    """
    _, d = signals.shape
    k1, k2 = jax.random.split(key)
    sig32 = signals.astype(jnp.float32)

    a, eff_std, b, kappa, m_back = cwfl.round_coefficients(
        state, sig32, normalize, precode)
    n1 = eff_std[:, None] * jax.random.normal(
        k1, (a.shape[0], d), jnp.float32)
    n2 = kappa[:, None] * jax.random.normal(
        k2, (a.shape[0], d), jnp.float32)

    new32, consensus = cwfl_round_auto(
        sig32, a, n1, b, n2, m_back, tile=tile,
        interpret=interpret, use_pallas=use_pallas)
    return new32.astype(signals.dtype), consensus


# ---------------------------------------------------------------------------
# Device collectives (shard_map over the data axis).
# ---------------------------------------------------------------------------

def ota_allreduce_tree(tree, plan: FLPlan, key: jax.Array,
                       axis_name: str = "data"):
    """Aggregate a local gradient/parameter pytree across ``axis_name`` with
    the hierarchical OTA collective.  Call INSIDE a ``jax.shard_map`` body;
    every rank returns the identical consensus tree."""
    flat = tree_flatten_vector(tree)
    out = hierarchical_ota_allreduce(flat, plan, key, axis_name)
    return tree_unflatten_vector(out, tree)


def build_gradient_allreduce(mesh, plan: FLPlan, axis_name: str = "data"):
    """Standalone jitted collective over K-stacked client pytrees.

    The returned ``agg(stacked_tree, key)`` maps leaves ``(K, ...)`` (client
    axis sharded over ``axis_name``; K must equal the axis size) to the
    same-shaped tree where every client slice holds the OTA consensus.
    """
    axis_size = dict(mesh.shape)[axis_name]
    if axis_size != plan.num_clients:
        # the per-rank weight-column lookup clamps out-of-range indices —
        # a silent wrong answer without this check.
        raise ValueError(
            f"plan has {plan.num_clients} clients but mesh axis "
            f"{axis_name!r} has {axis_size} ranks; one client per rank")

    def agg(stacked_tree, key):
        def body(local_tree, key):
            local = jax.tree.map(lambda x: x[0], local_tree)
            out = ota_allreduce_tree(local, plan, key, axis_name)
            return jax.tree.map(lambda x: x[None], out)

        specs = jax.tree.map(lambda _: P(axis_name), stacked_tree)
        f = shard_map(body, mesh=mesh, in_specs=(specs, P()),
                      out_specs=specs)
        return f(stacked_tree, key)

    return jax.jit(agg)
