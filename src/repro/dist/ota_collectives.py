"""Flat-vector / shard_map lowerings of the CWFL aggregation.

Two entry families:

* ``phase1_ota_flat`` / ``cwfl_aggregate_flat`` — Algorithm 1 on a flat
  ``(K, d)`` client-signal matrix.  The channel math (eq. 5 precoding,
  eq. 8 receiver scaling, lemma-2 noise) is the *same code* the reference
  operator :func:`repro.core.cwfl.aggregate` uses; the phase-1 MAC —
  ``W @ S + N`` over the d-dimensional flattened parameters, the per-round
  hot spot — is routed through the Pallas ``ota_aggregate`` kernel when the
  vector is large enough to benefit (``d >= PALLAS_MIN_DIM``).
* ``ota_allreduce_tree`` / ``build_gradient_allreduce`` — the device
  collective: the hierarchical two-phase OTA all-reduce applied to
  gradient/parameter pytrees across the mesh's ``data`` axis (one client
  per data rank), either inside an existing ``jax.shard_map`` body or as a
  standalone jitted collective.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import cwfl
from repro.core.cwfl import CWFLState
from repro.dist.fl_integration import FLPlan, hierarchical_ota_allreduce
from repro.kernels.ota_aggregate import DEFAULT_TILE
from repro.kernels.ota_aggregate import ota_aggregate as _pallas_ota
from repro.kernels.ref import ota_aggregate_ref
from repro.utils import tree_flatten_vector, tree_unflatten_vector

# Below this flat dimension the (C, K) matmul is too small for the kernel's
# tile machinery to pay off; the jnp reference is a single fused matmul.
PALLAS_MIN_DIM = 512


def phase1_ota_flat(signals: jnp.ndarray, state: CWFLState, key: jax.Array,
                    *, normalize: bool = True, precode: bool = True,
                    tile: int = DEFAULT_TILE,
                    interpret: Optional[bool] = None,
                    use_pallas: Optional[bool] = None) -> jnp.ndarray:
    """Phase-1 OTA MAC on flat vectors: ``(K, d) -> (C, d)`` (eq. 8).

    Matches :func:`repro.core.cwfl.aggregate`'s phase 1 leaf-for-leaf when
    the pytree is flattened to one vector per client.  ``interpret``
    defaults to the Pallas interpreter off-TPU (CPU validation) and the
    compiled kernel on TPU.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    _, d = signals.shape
    sig32 = signals.astype(jnp.float32)
    a = cwfl.phase1_weights(state)
    if precode:
        mean_sq = jnp.mean(jnp.square(sig32), axis=1)          # E‖θ‖²/use
        a = a * cwfl.precode_scale(state, mean_sq)[None, :]
    eff_std = state.head_noise_std / jnp.sqrt(state.total_power)
    if normalize:
        rows = jnp.maximum(a.sum(axis=1, keepdims=True), 1e-12)
        a = a / rows
        eff_std = eff_std / rows[:, 0]
    noise = eff_std[:, None] * jax.random.normal(
        key, (a.shape[0], d), jnp.float32)
    if use_pallas is None:
        use_pallas = d >= PALLAS_MIN_DIM
    if use_pallas:
        return _pallas_ota(sig32, a, noise, tile=tile, interpret=interpret)
    return ota_aggregate_ref(sig32, a, noise)


def cwfl_aggregate_flat(signals: jnp.ndarray, state: CWFLState,
                        key: jax.Array, *, normalize: bool = True,
                        precode: bool = True, tile: int = DEFAULT_TILE,
                        interpret: Optional[bool] = None,
                        use_pallas: Optional[bool] = None):
    """Full Algorithm 1 on a flat ``(K, d)`` matrix.

    Returns ``(new_signals (K, d), consensus (d,))`` — the flat-vector twin
    of :func:`repro.core.cwfl.aggregate` (exactly equal in the noiseless
    case; noise keys are split differently per leaf in the pytree path).
    """
    k1, k2 = jax.random.split(key)
    theta_tilde = phase1_ota_flat(signals, state, k1, normalize=normalize,
                                  precode=precode, tile=tile,
                                  interpret=interpret, use_pallas=use_pallas)

    b, kappa = cwfl.phase2_weights(state, normalize)
    theta_bar = b @ theta_tilde + kappa[:, None] * jax.random.normal(
        k2, theta_tilde.shape, jnp.float32)

    new = (state.plan.membership.T @ theta_bar).astype(signals.dtype)
    consensus = jnp.mean(theta_bar, axis=0)
    return new, consensus


# ---------------------------------------------------------------------------
# Device collectives (shard_map over the data axis).
# ---------------------------------------------------------------------------

def ota_allreduce_tree(tree, plan: FLPlan, key: jax.Array,
                       axis_name: str = "data"):
    """Aggregate a local gradient/parameter pytree across ``axis_name`` with
    the hierarchical OTA collective.  Call INSIDE a ``jax.shard_map`` body;
    every rank returns the identical consensus tree."""
    flat = tree_flatten_vector(tree)
    out = hierarchical_ota_allreduce(flat, plan, key, axis_name)
    return tree_unflatten_vector(out, tree)


def build_gradient_allreduce(mesh, plan: FLPlan, axis_name: str = "data"):
    """Standalone jitted collective over K-stacked client pytrees.

    The returned ``agg(stacked_tree, key)`` maps leaves ``(K, ...)`` (client
    axis sharded over ``axis_name``; K must equal the axis size) to the
    same-shaped tree where every client slice holds the OTA consensus.
    """
    from jax.sharding import PartitionSpec as P

    axis_size = dict(mesh.shape)[axis_name]
    if axis_size != plan.num_clients:
        # the per-rank weight-column lookup clamps out-of-range indices —
        # a silent wrong answer without this check.
        raise ValueError(
            f"plan has {plan.num_clients} clients but mesh axis "
            f"{axis_name!r} has {axis_size} ranks; one client per rank")

    def agg(stacked_tree, key):
        def body(local_tree, key):
            local = jax.tree.map(lambda x: x[0], local_tree)
            out = ota_allreduce_tree(local, plan, key, axis_name)
            return jax.tree.map(lambda x: x[None], out)

        from repro.dist import shard_map

        specs = jax.tree.map(lambda _: P(axis_name), stacked_tree)
        f = shard_map(body, mesh=mesh, in_specs=(specs, P()),
                      out_specs=specs)
        return f(stacked_tree, key)

    return jax.jit(agg)
