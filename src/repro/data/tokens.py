"""Synthetic LM token streams (for the transformer examples/smoke tests).

A fixed random first-order Markov chain over the vocabulary gives sequences
with learnable structure (per-token cross-entropy drops well below uniform as
the model learns the transition matrix). Offline container ⇒ no real corpora.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def make_token_dataset(key: jax.Array, vocab_size: int, num_sequences: int,
                       seq_len: int, branching: int = 8):
    """Returns int32 tokens (num_sequences, seq_len + 1); use [:-1]/[1:] as
    inputs/targets.  Each token transitions to one of ``branching`` successors
    under a fixed random table, with occasional uniform resets."""
    k_table, k_start, k_choice, k_reset, k_resetv = jax.random.split(key, 5)
    table = jax.random.randint(k_table, (vocab_size, branching), 0, vocab_size)

    starts = jax.random.randint(k_start, (num_sequences,), 0, vocab_size)
    choices = jax.random.randint(k_choice, (num_sequences, seq_len), 0, branching)
    resets = jax.random.bernoulli(k_reset, 0.02, (num_sequences, seq_len))
    reset_vals = jax.random.randint(k_resetv, (num_sequences, seq_len), 0,
                                    vocab_size)

    def step(tok, inp):
        choice, reset, rv = inp
        nxt = table[tok, choice]
        nxt = jnp.where(reset, rv, nxt)
        return nxt, nxt

    def gen(s, ch, rs, rv):
        _, seq = jax.lax.scan(step, s, (ch, rs, rv))
        return jnp.concatenate([s[None], seq])

    return jax.vmap(gen)(starts, choices, resets, reset_vals).astype(jnp.int32)
