from repro.data.synthetic import (
    SyntheticImageConfig,
    make_synthetic_images,
    partition_iid,
    partition_noniid,
)
from repro.data.tokens import make_token_dataset
