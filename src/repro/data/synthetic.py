"""Synthetic stand-ins for MNIST / CIFAR (container is offline; DESIGN.md §8).

Images are drawn from a fixed random *teacher*: each of the 10 classes has a
smooth prototype image; a sample is prototype[y] + structured noise. A small
MLP/CNN reaches high accuracy on it, and the FL dynamics the paper studies
(noisy OTA aggregation, non-IID label sharding) are preserved:

* ``mnist-like``: 28×28×1, 60k train / 10k test, 10 classes.
* ``cifar-like``: 32×32×3, 50k train / 10k test, 10 classes.

Partitioners follow §V exactly: IID = random equal split across K clients;
non-IID = sort by label, cut into 200 disjoint shards, deal ``shards_per
client`` shards to each client.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticImageConfig:
    name: str = "mnist-like"
    height: int = 28
    width: int = 28
    channels: int = 1
    num_classes: int = 10
    num_train: int = 60000
    num_test: int = 10000
    noise_std: float = 0.35      # intra-class variability
    smoothness: int = 4          # prototype low-res grid (upsampled -> smooth)

    @staticmethod
    def mnist_like(num_train: int = 60000, num_test: int = 10000):
        return SyntheticImageConfig("mnist-like", 28, 28, 1, 10,
                                    num_train, num_test)

    @staticmethod
    def cifar_like(num_train: int = 50000, num_test: int = 10000):
        return SyntheticImageConfig("cifar-like", 32, 32, 3, 10,
                                    num_train, num_test)


def _prototypes(key, cfg: SyntheticImageConfig) -> jnp.ndarray:
    """Smooth class prototypes: low-res noise, bilinear-upsampled."""
    low = jax.random.normal(
        key, (cfg.num_classes, cfg.smoothness, cfg.smoothness, cfg.channels))
    protos = jax.image.resize(
        low, (cfg.num_classes, cfg.height, cfg.width, cfg.channels),
        method="bilinear")
    return protos / jnp.maximum(jnp.std(protos), 1e-6)


def make_synthetic_images(key: jax.Array, cfg: SyntheticImageConfig
                          ) -> Tuple[Tuple[jnp.ndarray, jnp.ndarray],
                                     Tuple[jnp.ndarray, jnp.ndarray]]:
    """Returns ((x_train, y_train), (x_test, y_test))."""
    k_proto, k_ytr, k_yte, k_ntr, k_nte = jax.random.split(key, 5)
    protos = _prototypes(k_proto, cfg)

    def sample(ky, kn, n):
        y = jax.random.randint(ky, (n,), 0, cfg.num_classes)
        noise = cfg.noise_std * jax.random.normal(
            kn, (n, cfg.height, cfg.width, cfg.channels))
        x = protos[y] + noise
        return x.astype(jnp.float32), y

    train = sample(k_ytr, k_ntr, cfg.num_train)
    test = sample(k_yte, k_nte, cfg.num_test)
    return train, test


# ---------------------------------------------------------------------------
# Client partitioners (paper §V).
# ---------------------------------------------------------------------------

def partition_iid(key: jax.Array, x: jnp.ndarray, y: jnp.ndarray,
                  num_clients: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Random equal split. Returns stacked (K, N_k, ...) arrays."""
    n = x.shape[0]
    per = n // num_clients
    perm = jax.random.permutation(key, n)[: per * num_clients]
    xs = x[perm].reshape((num_clients, per) + x.shape[1:])
    ys = y[perm].reshape((num_clients, per))
    return xs, ys


def partition_noniid(key: jax.Array, x: jnp.ndarray, y: jnp.ndarray,
                     num_clients: int, shards_per_client: int,
                     num_shards: int = 200
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Paper's label-sorted sharding: sort by class, 200 disjoint shards,
    deal ``shards_per_client`` to each client (MNIST: 4, CIFAR: 7)."""
    n = x.shape[0]
    order = jnp.argsort(y, stable=True)
    usable = (n // num_shards) * num_shards
    order = order[:usable]
    shard_size = usable // num_shards
    shards = order.reshape(num_shards, shard_size)
    shard_perm = jax.random.permutation(key, num_shards)
    need = num_clients * shards_per_client
    if need > num_shards:
        raise ValueError(f"need {need} shards but only {num_shards} exist")
    chosen = shard_perm[:need].reshape(num_clients, shards_per_client)
    idx = shards[chosen].reshape(num_clients, shards_per_client * shard_size)
    return x[idx], y[idx]


def label_histogram(ys: jnp.ndarray, num_classes: int = 10) -> np.ndarray:
    """(K, num_classes) per-client label counts — for non-IID sanity checks."""
    K = ys.shape[0]
    out = np.zeros((K, num_classes), np.int64)
    ys = np.asarray(ys)
    for k in range(K):
        out[k] = np.bincount(ys[k], minlength=num_classes)
    return out
