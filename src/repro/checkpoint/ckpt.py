"""Checkpointing: npz-based pytree save/load with step management.

Layout: <dir>/step_<N>/arrays.npz + tree.json (pytree structure + dtypes).
Works for parameter pytrees, optimizer states and FL client stacks alike.

Non-numpy dtypes (bfloat16): ``np.savez`` cannot serialize ml_dtypes
arrays, so bf16 leaves are stored as their raw uint16 bit patterns and
the TRUE dtype is recorded in ``tree.json``; :func:`load_checkpoint`
re-views the bits back before casting into the template.  The round-trip
is a reinterpreting ``view`` on both sides — never a value conversion —
so bf16 checkpoints restore bit-exactly (the resume-determinism contract
of `repro.sim.engine.run_rounds` depends on it).
"""
from __future__ import annotations

import json
import re
from pathlib import Path
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

# dtypes np.savez can't natively store → (wire dtype, bit-view round-trip).
_WIRE_DTYPES = {"bfloat16": (np.uint16, ml_dtypes.bfloat16)}


def _flatten_with_names(tree) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path) or "_root"
        out[name] = np.asarray(leaf)
    return out


def save_checkpoint(directory: str | Path, step: int, tree: Any) -> Path:
    d = Path(directory) / f"step_{step:08d}"
    d.mkdir(parents=True, exist_ok=True)
    arrays = _flatten_with_names(tree)
    dtypes = {k: str(v.dtype) for k, v in arrays.items()}
    wire = {k: (v.view(_WIRE_DTYPES[str(v.dtype)][0])
                if str(v.dtype) in _WIRE_DTYPES else v)
            for k, v in arrays.items()}
    np.savez(d / "arrays.npz", **wire)
    meta = {
        "step": step,
        "treedef": str(jax.tree.structure(tree)),
        "names": list(arrays.keys()),
        "dtypes": dtypes,
    }
    (d / "tree.json").write_text(json.dumps(meta))
    return d


def load_checkpoint(directory: str | Path, template: Any,
                    step: Optional[int] = None) -> Any:
    """Load into the structure of ``template`` (shapes/dtypes validated)."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    d = directory / f"step_{step:08d}"
    if not (d / "arrays.npz").exists():
        raise FileNotFoundError(f"checkpoint step directory {d} has no "
                                f"arrays.npz (is step {step} complete?)")
    data = np.load(d / "arrays.npz")
    meta_path = d / "tree.json"
    saved_dtypes = (json.loads(meta_path.read_text()).get("dtypes", {})
                    if meta_path.exists() else {})
    names = list(_flatten_with_names(template).keys())
    leaves_t, treedef = jax.tree_util.tree_flatten(template)
    out = []
    for name, leaf in zip(names, leaves_t):
        if name not in data:
            raise KeyError(f"{name}: missing from {d / 'arrays.npz'} — "
                           f"template does not match this checkpoint")
        arr = data[name]
        true_dtype = saved_dtypes.get(name)
        if true_dtype in _WIRE_DTYPES:
            arr = arr.view(_WIRE_DTYPES[true_dtype][1])
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{name} (in {d}): checkpoint shape "
                             f"{arr.shape} != template {leaf.shape}")
        out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def latest_step(directory: str | Path) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = [int(m.group(1)) for p in directory.iterdir()
             if (m := re.fullmatch(r"step_(\d+)", p.name))]
    return max(steps) if steps else None
