"""Checkpointing: npz-based pytree save/load with step management.

Layout: <dir>/step_<N>/arrays.npz + tree.json (pytree structure + dtypes).
Works for parameter pytrees, optimizer states and FL client stacks alike.
"""
from __future__ import annotations

import json
import re
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_names(tree) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path) or "_root"
        out[name] = np.asarray(leaf)
    return out


def save_checkpoint(directory: str | Path, step: int, tree: Any) -> Path:
    d = Path(directory) / f"step_{step:08d}"
    d.mkdir(parents=True, exist_ok=True)
    arrays = _flatten_with_names(tree)
    np.savez(d / "arrays.npz", **arrays)
    structure = jax.tree.map(lambda x: None, tree)
    meta = {
        "step": step,
        "treedef": str(jax.tree.structure(tree)),
        "names": list(arrays.keys()),
        "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
    }
    (d / "tree.json").write_text(json.dumps(meta))
    del structure
    return d


def load_checkpoint(directory: str | Path, template: Any,
                    step: Optional[int] = None) -> Any:
    """Load into the structure of ``template`` (shapes/dtypes validated)."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    d = directory / f"step_{step:08d}"
    data = np.load(d / "arrays.npz")
    names = list(_flatten_with_names(template).keys())
    leaves_t, treedef = jax.tree_util.tree_flatten(template)
    out = []
    for name, leaf in zip(names, leaves_t):
        arr = data[name]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{name}: checkpoint shape {arr.shape} != "
                             f"template {leaf.shape}")
        out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def latest_step(directory: str | Path) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = [int(m.group(1)) for p in directory.iterdir()
             if (m := re.fullmatch(r"step_(\d+)", p.name))]
    return max(steps) if steps else None
