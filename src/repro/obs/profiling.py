"""Profiling hooks: phase wall timers + optional jax profiler capture
(DESIGN.md §Obs).

`PhaseTimers` splits a run's wall time into the phases that matter for
the scanned engine — ``trace_compile`` (jit trace + XLA compile via the
AOT ``lower().compile()`` path), ``execute`` (device time to
``block_until_ready``), and ``gather`` (device→host transfer of the
metric buffers) — so BENCH/sim regressions can be attributed to the
right layer instead of a single opaque wall number.  Timers are opt-in:
with ``timers=None`` the engine's default jit path is untouched.

:func:`profiler_trace` wraps a run in ``jax.profiler.trace`` when a
directory is given (TensorBoard-loadable), and is a no-op otherwise.
"""
from __future__ import annotations

import contextlib
import time
from typing import Optional


class PhaseTimers:
    """Accumulating named wall timers: ``with timers.phase("execute"):``.
    Re-entering a phase accumulates (loop-mode rounds sum into one
    ``execute`` figure)."""

    def __init__(self):
        self.seconds: dict[str, float] = {}

    @contextlib.contextmanager
    def phase(self, name: str):
        t0 = time.perf_counter()
        try:
            yield self
        finally:
            self.seconds[name] = (self.seconds.get(name, 0.0)
                                  + time.perf_counter() - t0)

    def as_dict(self) -> dict:
        return {k: round(v, 6) for k, v in sorted(self.seconds.items())}


@contextlib.contextmanager
def profiler_trace(trace_dir: Optional[str] = None):
    """``jax.profiler.trace(trace_dir)`` when a directory is given
    (creates it if needed); a no-op context otherwise."""
    if not trace_dir:
        yield
        return
    import jax
    with jax.profiler.trace(str(trace_dir)):
        yield
