"""JSONL event/metrics sink (DESIGN.md §Obs).

One run = one append-only JSONL stream: a ``manifest`` record first
(`repro.obs.manifest`), one ``round`` record per (trajectory, round)
carrying the metrics and the `RoundTelemetry` fields, and a final
``summary`` record (final accuracies, phase timers).  The stream is the
contract `examples/obs_report.py` renders from, and what the sim-smoke CI
job uploads next to BENCH_*.json.
"""
from __future__ import annotations

import json
from typing import Any, Optional

import numpy as np

from repro.obs.manifest import to_jsonable


class JsonlSink:
    """Append-only JSONL writer; one json object per line, flushed per
    record so a crashed run keeps everything emitted so far."""

    def __init__(self, path: str):
        self.path = str(path)
        self._f = open(self.path, "w")

    def emit(self, kind: str, **fields) -> None:
        rec = {"type": kind, **{k: to_jsonable(v) for k, v in fields.items()}}
        self._f.write(json.dumps(rec) + "\n")
        self._f.flush()

    def close(self) -> None:
        self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


def _tele_at(tele, idx: tuple):
    """Slice one (trajectory..., round) record out of a stacked telemetry
    pytree and return it as a plain nested dict."""
    import jax
    sliced = jax.tree.map(lambda x: np.asarray(x)[idx], tele)
    d = sliced._asdict()
    d["extras"] = dict(d["extras"])
    return d


def write_history(path, history: dict, manifest: Optional[dict] = None,
                  timings: Optional[dict] = None) -> int:
    """Serialize an engine history dict (`run_rounds` / `run_monte_carlo`
    output, optionally carrying ``history["telemetry"]``) into a JSONL
    stream at ``path``.  Returns the number of records written.

    Single-trajectory histories emit one ``round`` record per round;
    Monte-Carlo histories emit one per (seed[, snr], round) tagged with
    the trajectory indices and resolved seed/SNR values.
    """
    loss = np.asarray(history["train_loss"])
    acc = np.asarray(history["test_acc"])
    tele = history.get("telemetry")
    seeds = history.get("seeds")
    snr_grid = history.get("snr_grid")
    seeds = None if seeds is None else np.asarray(seeds)
    snr_grid = None if snr_grid is None else np.asarray(snr_grid)

    n = 0
    with JsonlSink(path) as sink:
        if manifest is not None:
            sink.emit("manifest", **manifest)
            n += 1
        T = loss.shape[-1]
        for traj_idx in np.ndindex(loss.shape[:-1]):
            tags: dict[str, Any] = {}
            if traj_idx:
                tags["traj"] = list(traj_idx)
                if seeds is not None:
                    tags["seed"] = int(seeds[traj_idx[0]])
                if snr_grid is not None and len(traj_idx) > 1:
                    tags["snr_db"] = float(snr_grid[traj_idx[1]])
            for t in range(T):
                idx = traj_idx + (t,)
                rec = {"round": t + 1, **tags,
                       "train_loss": float(loss[idx]),
                       "test_acc": float(acc[idx])}
                if tele is not None:
                    rec["telemetry"] = _tele_at(tele, idx)
                sink.emit("round", **rec)
                n += 1
        summary: dict[str, Any] = {
            "rounds": int(T),
            "trajectories": int(np.prod(loss.shape[:-1], dtype=int)),
            "final_acc": to_jsonable(acc[..., -1]),
        }
        if tele is not None:
            summary["cum_channel_uses"] = to_jsonable(
                np.asarray(tele.cum_channel_uses)[..., -1])
            summary["cum_symbols"] = to_jsonable(
                np.asarray(tele.cum_symbols)[..., -1])
        if timings is not None:
            summary["timings"] = timings
        sink.emit("summary", **summary)
        n += 1
    return n


def read_run(path) -> dict:
    """Parse a JSONL run back into ``{"manifest": dict|None,
    "rounds": [..], "summary": dict|None, "events": [..]}``."""
    manifest, rounds, summary, events = None, [], None, []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            kind = rec.get("type")
            if kind == "manifest":
                manifest = rec
            elif kind == "round":
                rounds.append(rec)
            elif kind == "summary":
                summary = rec
            else:
                events.append(rec)
    return {"manifest": manifest, "rounds": rounds, "summary": summary,
            "events": events}
