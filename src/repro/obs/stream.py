"""In-scan streaming telemetry: the live path (DESIGN.md §Obs-live).

`repro.obs.telemetry` made every round observable — but only *post hoc*:
`RoundTelemetry` rides the scan outputs and is unreadable until the whole
trajectory returns.  This module drains the same pytree to the host
*while the scan is running* via `jax.experimental.io_callback`, behind
the same STATIC-flag discipline the ``telemetry=`` flag established:

* ``stream=None`` (default) adds **zero** equations — the traced jaxpr
  is byte-identical to the streaming-unaware build (pinned by
  ``tests/test_stream.py``);
* ``stream=RoundStream(...)`` inserts one effectful callback per round
  whose operands are values the body has *already computed* (the round's
  ``jnp.mean(losses)``, ``acc`` and telemetry leaves) — never a second
  reduction over a fusion-sensitive buffer — so streamed runs leave
  ``train_loss``/``test_acc`` bit-for-bit unchanged.

Ordering and fan-in (validated empirically on this jax):

* single-trajectory scans and `shard_map` bodies tap PER ROUND inside
  the scan body with ``ordered=True`` — records arrive on the host in
  round order while the trajectory runs (:func:`stream_tap`);
* Monte-Carlo sweeps `vmap` the trajectory, where the in-body tap is
  impossible twice over: ordered callbacks cannot be batched ("Cannot
  `vmap` ordered IO callback"), and even unordered, a batched in-scan
  consumer of the round's loss re-fuses the vmapped reduction and
  drifts the metrics by 1 ulp.  They tap PER TRAJECTORY after the scan
  instead (:func:`stream_trajectory_tap`): the operands are the scan's
  round-stacked output buffers — already materialized, so the consumer
  is provably fusion-neutral — and the host expands them into the same
  per-round records, tagged ``(round, seed, snr)`` because arrival
  order means nothing under a batched unordered callback;
* ordered effects are illegal inside `lax.cond`, so rank gating on a
  mesh can never be a traced branch around the callback.  The clients
  mesh passes ``lax.axis_index("clients")`` as a callback operand and
  the *host* drops records from nonzero ranks; the mc mesh (where the
  tap sits under `vmap` and `eval_shape` must trace it outside the mesh)
  instead scopes the stream to rank 0's trajectory chunk by ``(seed,
  snr)`` tag — same "rank-0 emit", no axis name needed at trace time.

The host side is :class:`RoundStream`: a bounded ring buffer of raw
numpy records (bitwise comparable against post-hoc telemetry) fanned out
to pluggable sinks — :class:`MemorySink` for tests, JSONL append
(tail-able mid-run by ``examples/watch_run.py``), and a Prometheus-style
textfile — with an optional `repro.obs.monitor.Monitor` evaluating alert
rules on every record.
"""
from __future__ import annotations

import json
import os
import threading
from collections import deque
from typing import Any, Optional, Sequence

import numpy as np

from repro.obs.manifest import to_jsonable

STREAM_SCHEMA = "repro.obs.stream/v1"


# ---------------------------------------------------------------------------
# sinks
# ---------------------------------------------------------------------------

class MemorySink:
    """Keeps every record as-is (numpy payloads preserved) — the bitwise
    fixture for tests; no serialization loss."""

    def __init__(self):
        self.records: list[dict] = []

    def write(self, record: dict) -> None:
        self.records.append(record)

    def close(self) -> None:
        pass

    def of_type(self, kind: str) -> list[dict]:
        return [r for r in self.records if r.get("type") == kind]


class JsonlStreamSink:
    """Append-only JSONL, one json object per line, flushed per record so
    ``examples/watch_run.py`` (or plain ``tail -f``) can follow the run
    mid-flight.  ``append=True`` reopens an existing stream — the resume
    path: a resumed run keeps appending to the same file and the absolute
    round tags keep the stream monotone."""

    def __init__(self, path, append: bool = False):
        self.path = str(path)
        self._f = open(self.path, "a" if append else "w")

    def write(self, record: dict) -> None:
        self._f.write(json.dumps(to_jsonable(record)) + "\n")
        self._f.flush()

    def close(self) -> None:
        self._f.close()


class PrometheusSink:
    """Prometheus-style textfile exporter: rewrites ``path`` atomically on
    every record with the latest gauge per (seed, snr) trajectory plus a
    cumulative alert counter — point node_exporter's textfile collector
    (or a test) at it."""

    _GAUGES = (
        ("round", "last streamed round (1-based)"),
        ("train_loss", "streamed mean train loss"),
        ("test_acc", "streamed test accuracy"),
        ("participants", "effective transmit-side participation"),
        ("consensus_drift_max", "max per-site ||theta_c - theta_bar||"),
        ("cum_channel_uses", "cumulative OTA channel uses"),
        ("cum_symbols", "cumulative scalar symbols"),
    )

    def __init__(self, path, prefix: str = "repro"):
        self.path = str(path)
        self.prefix = prefix
        self._latest: dict[tuple, dict] = {}
        self._alerts = 0
        self._flush()

    def write(self, record: dict) -> None:
        kind = record.get("type")
        if kind == "alert":
            self._alerts += 1
        elif kind == "stream":
            key = (record.get("seed"), record.get("snr_db"))
            tele = record.get("telemetry") or {}
            drift = np.asarray(tele.get("consensus_drift", np.nan))
            self._latest[key] = {
                "round": record.get("round"),
                "train_loss": record.get("train_loss"),
                "test_acc": record.get("test_acc"),
                "participants": tele.get("participants"),
                "consensus_drift_max": (float(np.max(drift))
                                        if drift.size else None),
                "cum_channel_uses": tele.get("cum_channel_uses"),
                "cum_symbols": tele.get("cum_symbols"),
            }
        else:
            return
        self._flush()

    def _label(self, key: tuple) -> str:
        seed, snr = key
        parts = []
        if seed is not None:
            parts.append(f'seed="{seed}"')
        if snr is not None:
            parts.append(f'snr_db="{snr:g}"')
        return "{" + ",".join(parts) + "}" if parts else ""

    def _flush(self) -> None:
        lines = []
        for name, help_txt in self._GAUGES:
            metric = f"{self.prefix}_{name}"
            lines.append(f"# HELP {metric} {help_txt}")
            lines.append(f"# TYPE {metric} gauge")
            for key, vals in sorted(self._latest.items(),
                                    key=lambda kv: repr(kv[0])):
                v = vals.get(name)
                if v is None:
                    continue
                lines.append(f"{metric}{self._label(key)} {float(v):g}")
        metric = f"{self.prefix}_alerts_total"
        lines.append(f"# HELP {metric} alert records emitted")
        lines.append(f"# TYPE {metric} counter")
        lines.append(f"{metric} {self._alerts}")
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            f.write("\n".join(lines) + "\n")
        os.replace(tmp, self.path)

    def close(self) -> None:
        self._flush()


# ---------------------------------------------------------------------------
# the host-side stream
# ---------------------------------------------------------------------------

def _np_tree(obj):
    """Materialize a callback payload pytree as nested plain dicts of
    numpy arrays (bit-preserving; no float round-trips)."""
    if isinstance(obj, dict):
        return {k: _np_tree(v) for k, v in obj.items()}
    if hasattr(obj, "_asdict"):
        return _np_tree(obj._asdict())
    if isinstance(obj, (list, tuple)):
        return [_np_tree(v) for v in obj]
    return np.asarray(obj)


def _tree_index(obj, t: int):
    """Slice index ``t`` off every leaf's leading (round) axis of a
    materialized payload tree."""
    if isinstance(obj, dict):
        return {k: _tree_index(v, t) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_tree_index(v, t) for v in obj]
    return obj[t]


class RoundStream:
    """Host endpoint of the in-scan tap: bounded ring buffer + sink
    fan-out + optional alert monitor.

    The traced side calls :func:`stream_tap`, which lowers to one
    ``io_callback`` per round invoking :meth:`_emit` with the round's
    tags and telemetry.  ``_emit`` is host Python — it may run from XLA
    callback threads, hence the lock — and must never raise (an
    exception would poison the running computation), so sink failures
    are swallowed into ``self.errors``.

    ``capacity`` bounds the ring (old records drop; sinks saw them
    already).  ``scope_to_trajectories`` restricts the stream to an
    explicit ``(seed, snr)`` allow-list — how the mc-sharded path
    implements rank-0 emit (see module docstring).  ``should_abort``
    re-exports the monitor's escalation decision; the engine's
    checkpointed drivers poll it at segment boundaries
    (checkpoint-then-stop, resumable).
    """

    def __init__(self, sinks: Sequence = (), monitor=None,
                 capacity: int = 4096):
        self.sinks = list(sinks)
        self.monitor = monitor
        self.ring: deque = deque(maxlen=int(capacity))
        self.errors: list[str] = []
        self.emitted = 0
        self.dropped = 0
        self._scope: Optional[set] = None
        self._lock = threading.Lock()

    # -- configuration ------------------------------------------------

    def scope_to_trajectories(self, tags) -> None:
        """Keep only records whose ``(seed, snr_db)`` is in ``tags``
        (snr ``None`` matches the no-sweep tap).  Used by
        `monte_carlo_sharded` to scope the stream to rank 0's chunk."""
        self._scope = {(int(s), None if q is None else float(np.float32(q)))
                       for s, q in tags}

    # -- host callback ------------------------------------------------

    def _emit(self, payload) -> None:
        """Per-round callback target (the ordered in-body tap)."""
        try:
            p = _np_tree(payload)
            tags = self._tags(p)
            if tags is None:
                with self._lock:
                    self.dropped += 1
                return
            self._ingest(self._round_record(
                tags, int(p["t"]), p["loss"], p["acc"], p["tele"]))
        except Exception as e:  # never poison the running computation
            self.errors.append(repr(e))

    def _emit_trajectory(self, payload) -> None:
        """Per-trajectory callback target (the unordered post-scan tap
        on vmapped Monte-Carlo paths): ``loss``/``acc``/``tele`` arrive
        round-stacked (T leading) and expand into T round records."""
        try:
            p = _np_tree(payload)
            tags = self._tags(p)
            if tags is None:
                with self._lock:
                    self.dropped += 1
                return
            T = int(np.asarray(p["loss"]).shape[0])
            for t in range(T):
                self._ingest(self._round_record(
                    tags, t, p["loss"][t], p["acc"][t],
                    _tree_index(p["tele"], t)))
        except Exception as e:
            self.errors.append(repr(e))

    def _tags(self, p) -> Optional[tuple]:
        """(seed, snr_db) of a materialized payload, or ``None`` when the
        record must drop (nonzero rank / outside the trajectory scope)."""
        if int(p["rank"]) != 0:
            return None
        snr = float(p["snr"])
        snr_db = None if np.isnan(snr) else snr
        seed = int(p["seed"])
        if self._scope is not None and (seed, snr_db) not in self._scope:
            return None
        return seed, snr_db

    def _round_record(self, tags, t: int, loss, acc, tele) -> dict:
        seed, snr_db = tags
        return {
            "type": "stream",
            "schema": STREAM_SCHEMA,
            "round": int(t) + 1,
            "seed": seed,
            "snr_db": snr_db,
            "train_loss": loss,
            "test_acc": acc,
            "telemetry": tele,
        }

    def _ingest(self, rec: dict) -> None:
        with self._lock:
            self.emitted += 1
            self.ring.append(rec)
            self._write(rec)
            if self.monitor is not None:
                for alert in self.monitor.observe(rec):
                    self._write(alert.to_record())

    def _write(self, rec: dict) -> None:
        for sink in self.sinks:
            try:
                sink.write(rec)
            except Exception as e:  # pragma: no cover - sink failure
                self.errors.append(repr(e))

    # -- host-side inspection -----------------------------------------

    def records(self) -> list[dict]:
        with self._lock:
            return list(self.ring)

    def for_trajectory(self, seed: Optional[int] = None,
                       snr_db: Optional[float] = None) -> list[dict]:
        """Records for one trajectory, sorted by round (unordered mc
        callbacks may interleave arrival order)."""
        out = [r for r in self.records()
               if (seed is None or r["seed"] == seed)
               and (snr_db is None or r["snr_db"] == snr_db)]
        return sorted(out, key=lambda r: r["round"])

    @property
    def should_abort(self) -> bool:
        return self.monitor is not None and self.monitor.should_abort

    @property
    def escalates(self) -> bool:
        """True when the attached monitor may request an abort — callers
        must then provide checkpoint machinery to stop into."""
        return (self.monitor is not None
                and getattr(self.monitor, "abort_on_alert", False))

    def close(self) -> None:
        for sink in self.sinks:
            try:
                sink.close()
            except Exception as e:  # pragma: no cover
                self.errors.append(repr(e))

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


# ---------------------------------------------------------------------------
# the traced-side tap
# ---------------------------------------------------------------------------

def _tap_payload(seed, snr, rank, loss, acc, telemetry) -> dict:
    import jax.numpy as jnp

    return {
        "seed": jnp.asarray(seed, jnp.int32),
        "snr": (jnp.full((), jnp.nan, jnp.float32) if snr is None
                else jnp.asarray(snr, jnp.float32)),
        "rank": (jnp.zeros((), jnp.int32) if rank is None
                 else jnp.asarray(rank, jnp.int32)),
        "loss": loss,
        "acc": acc,
        "tele": telemetry,
    }


def stream_tap(stream: RoundStream, *, t, seed, snr, loss, acc, telemetry,
               rank=None, ordered: bool = True) -> None:
    """Insert the per-round host callback into a traced scan body.

    All operands are values the body already holds — this function adds
    no arithmetic to the round.  ``t`` is the ABSOLUTE round index (a
    scan input sliced by the checkpoint driver, so resumed segments keep
    emitting absolute rounds); ``snr=None`` tags the record with
    ``snr_db: null``; ``rank`` is a traced mesh index (host drops
    nonzero ranks) or ``None`` outside meshes.

    Only for UNBATCHED scan bodies (single-trajectory runs, shard_map'd
    bodies).  Under `vmap` two things break: ordered callbacks cannot be
    batched at all, and even an unordered in-body tap gives the round's
    loss reduction a second in-scan consumer, which re-fuses the batched
    reduction and drifts the metrics by 1 ulp — use
    :func:`stream_trajectory_tap` after the scan instead (measured, and
    pinned by tests/test_stream.py's bitwise assertions)."""
    import jax.numpy as jnp
    from jax.experimental import io_callback

    payload = {"t": jnp.asarray(t, jnp.int32),
               **_tap_payload(seed, snr, rank, loss, acc, telemetry)}
    io_callback(stream._emit, None, payload, ordered=ordered)


def stream_trajectory_tap(stream: RoundStream, *, seed, snr, loss, acc,
                          telemetry, rank=None) -> None:
    """Insert a per-trajectory host callback AFTER a traced scan.

    The vmap-safe tap for Monte-Carlo sweeps: operands are the scan's
    round-stacked outputs — already-materialized buffers, so giving them
    a host consumer cannot re-fuse anything inside the scan and the
    swept metrics stay bit-for-bit identical.  Unordered (vmap batches
    the callback into one unbatched call per trajectory); the host
    expands the (T,)-stacked payload into T tagged round records, so
    downstream consumers see the same record schema as the live
    per-round tap."""
    from jax.experimental import io_callback

    payload = _tap_payload(seed, snr, rank, loss, acc, telemetry)
    io_callback(stream._emit_trajectory, None, payload, ordered=False)
