"""repro.obs — observability: in-scan round telemetry (post-hoc AND
live-streamed), run manifests, JSONL sinks, the channel-use ledger, the
alert monitor, and profiling hooks (DESIGN.md §Obs, §Obs-live).

The substrate every scale PR logs into: `RoundTelemetry` rides the
scenario engine's ``lax.scan`` (opt-in, bit-neutral when off),
`RoundStream` drains it to the host mid-run via an `io_callback` tap
(`stream.py`) with `Monitor` alert rules checking the paper's c/T and
eq. (5) envelopes in flight (`monitor.py`), `build_manifest` stamps
provenance into BENCH_*.json and scenario runs, `JsonlSink`/
`write_history` persist a run's event stream, and `examples/
obs_report.py` / `examples/watch_run.py` render it post-hoc / live.
"""
from repro.obs.ledger import (per_round_table, symbols_per_round,
                              uses_per_round)
from repro.obs.manifest import (build_manifest, config_hash, device_info,
                                git_revision, to_jsonable)
from repro.obs.monitor import (Alert, AlertRule, ConsensusDriftRule,
                               ConvergenceStallRule, Monitor,
                               NonFiniteLossRule, PowerBudgetRule,
                               QuarantineRateRule, default_rules)
from repro.obs.profiling import PhaseTimers, profiler_trace
from repro.obs.sink import JsonlSink, read_run, write_history
from repro.obs.stream import (JsonlStreamSink, MemorySink, PrometheusSink,
                              RoundStream, stream_tap)
from repro.obs.telemetry import (RoundTelemetry, build_round_telemetry,
                                 init_ledger, per_client_dim,
                                 stacked_consensus_drift)
