"""repro.obs — observability: in-scan round telemetry, run manifests,
JSONL sinks, the channel-use ledger, and profiling hooks (DESIGN.md §Obs).

The substrate every scale PR logs into: `RoundTelemetry` rides the
scenario engine's ``lax.scan`` (opt-in, bit-neutral when off),
`build_manifest` stamps provenance into BENCH_*.json and scenario runs,
`JsonlSink`/`write_history` persist a run's event stream, and
`examples/obs_report.py` renders it into per-cluster convergence and
communication-cost tables.
"""
from repro.obs.ledger import (per_round_table, symbols_per_round,
                              uses_per_round)
from repro.obs.manifest import (build_manifest, config_hash, device_info,
                                git_revision, to_jsonable)
from repro.obs.profiling import PhaseTimers, profiler_trace
from repro.obs.sink import JsonlSink, read_run, write_history
from repro.obs.telemetry import (RoundTelemetry, build_round_telemetry,
                                 init_ledger, per_client_dim,
                                 stacked_consensus_drift)
