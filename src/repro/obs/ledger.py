"""Channel-use accounting — the ONE source of truth for the paper's
communication-cost claim (§IV/§VI, DESIGN.md §Obs).

The per-round MAC-slot count of each aggregation strategy lives on the
`repro.strategies.Strategy` object itself (``Strategy.channel_uses`` —
pure arithmetic, traced-friendly, so the in-scan telemetry ledger and the
host-side benchmark tables can never disagree).  This module is the
host-side front door:

* :func:`uses_per_round` — resolve a strategy by name through the
  registry and evaluate its per-round slot count with concrete ints;
* :func:`per_round_table` — the paper's §IV comparison row (CWFL's
  C(C−1)+C vs decentralized K(K−1) vs a single server MAC), consumed by
  ``benchmarks/channel_uses.py`` and `examples/obs_report.py`;
* :func:`symbols_per_round` — slots × d: the actual scalar symbol count
  one sync of a d-dimensional model costs (each MAC slot carries one
  d-dimensional OTA superposition).

Accounting convention: one "channel use" is one scheduled MAC slot
(an OTA superposition or one directed head→head/node→node transmission),
exactly the unit of the paper's C(C−1)+C vs K(K−1) claim.  ``fedavg``
counts 0 — it is the genie-aided noiseless bound with no wireless
channel at all.
"""
from __future__ import annotations

from typing import Optional


def uses_per_round(strategy, num_clients: int,
                   num_clusters: Optional[int] = None,
                   participants=None):
    """Per-round channel uses of ``strategy`` (a registry name or a
    `Strategy` instance), delegated to ``Strategy.channel_uses``.

    ``participants`` (optional, may be traced): effective participant
    count after masking — only graph-based strategies whose slot count
    depends on who shows up (decentralized: P(P−1)) read it.
    """
    from repro.strategies import get_strategy
    return get_strategy(strategy).channel_uses(
        num_clients, num_clusters=num_clusters, participants=participants)


def symbols_per_round(strategy, dim: int, num_clients: int,
                      num_clusters: Optional[int] = None,
                      participants=None):
    """Scalar symbols per sync round: slots × d (one d-dim vector per slot)."""
    return uses_per_round(strategy, num_clients, num_clusters=num_clusters,
                          participants=participants) * dim


def per_round_table(num_clients: int, num_clusters: int) -> dict:
    """The paper's §IV efficiency comparison for one (K, C) point:
    CWFL's C(C−1) consensus uses + C OTA slots, vs K(K−1) for
    fully-decentralized consensus, vs 1 for a single-server OTA MAC.
    Every entry is evaluated from the registered strategy's own
    ``channel_uses`` — `repro.core.cwfl.channel_uses_per_round` and
    ``benchmarks/channel_uses.py`` both resolve through here.
    """
    return {
        "cwfl": uses_per_round("cwfl", num_clients, num_clusters),
        "decentralized": uses_per_round("decentralized", num_clients),
        "server_ota": uses_per_round("cotaf", num_clients),
    }
