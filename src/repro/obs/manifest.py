"""Run manifests: who/what/where provenance for every recorded run
(DESIGN.md §Obs).

BENCH_*.json and scenario result files used to record numbers with no
provenance — no git sha, device kind, or jax version — making the perf
trajectory unreproducible run-to-run.  :func:`build_manifest` stamps one
canonical provenance record: git revision (+dirty flag), jax/numpy/python
versions, backend and device kind/count, hostname, timestamps, the
resolved scenario/strategy names, the full config and a stable
``config_hash`` over (config, scenario, strategy) so runs with identical
protocols are identifiable across files.

Everything here is host-side stdlib + best-effort: a missing git binary
or a non-repo checkout degrades to ``git: None`` rather than failing the
run being recorded.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import platform
import socket
import subprocess
import time
from typing import Any, Optional

MANIFEST_SCHEMA = "repro.obs.manifest/v1"


def to_jsonable(obj: Any) -> Any:
    """Best-effort conversion to JSON-serializable structures: dataclasses
    → dicts, numpy/jax arrays → lists (0-d → scalars), tuples → lists.
    Unknown objects degrade to ``repr`` rather than raising — a manifest
    must never kill the run it documents."""
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return {f.name: to_jsonable(getattr(obj, f.name))
                for f in dataclasses.fields(obj)}
    if isinstance(obj, dict):
        return {str(k): to_jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple, set)):
        return [to_jsonable(v) for v in obj]
    if hasattr(obj, "_asdict"):                      # NamedTuple
        return to_jsonable(obj._asdict())
    if hasattr(obj, "tolist"):                       # numpy / jax arrays
        try:
            return to_jsonable(obj.tolist())
        except Exception:  # pragma: no cover - exotic array types
            return repr(obj)
    if hasattr(obj, "item"):                         # 0-d scalars
        try:
            return obj.item()
        except Exception:  # pragma: no cover
            return repr(obj)
    return repr(obj)


def config_hash(*objs: Any) -> str:
    """Stable 16-hex digest of the canonical JSON of ``objs`` — the run
    identity key: same (config, scenario, strategy) ⇒ same hash, across
    processes and json key orderings."""
    canon = json.dumps([to_jsonable(o) for o in objs], sort_keys=True,
                       separators=(",", ":"))
    return hashlib.sha256(canon.encode()).hexdigest()[:16]


def git_revision(cwd: Optional[str] = None) -> Optional[dict]:
    """``{"sha": <40-hex>, "dirty": bool}`` of the enclosing checkout, or
    ``None`` when git/the repo is unavailable (never raises)."""
    cwd = cwd or os.path.dirname(os.path.abspath(__file__))
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=cwd, capture_output=True,
            text=True, timeout=10, check=True).stdout.strip()
        dirty = subprocess.run(
            ["git", "status", "--porcelain"], cwd=cwd, capture_output=True,
            text=True, timeout=10, check=True).stdout.strip() != ""
        return {"sha": sha, "dirty": dirty}
    except Exception:
        return None


def device_info() -> dict:
    """Backend + device kind/count of the current jax runtime."""
    import jax
    devs = jax.devices()
    return {
        "backend": jax.default_backend(),
        "device_kind": devs[0].device_kind if devs else "unknown",
        "device_count": len(devs),
    }


def build_manifest(cfg: Any = None, scenario: Any = None,
                   strategy: Any = None, mesh: Any = None,
                   extra: Optional[dict] = None) -> dict:
    """One provenance record for a run.

    ``cfg``: the `FLConfig` (or any dataclass/dict); ``scenario``: a
    `Scenario` or its name; ``strategy``: a `Strategy` or its name;
    ``mesh``: an optional jax `Mesh` (its axis→size shape is recorded);
    ``extra``: free-form caller fields merged at the top level (bench
    name, CLI argv, ...).
    """
    import jax
    import numpy as np

    scenario_name = getattr(scenario, "name", scenario)
    strategy_name = getattr(strategy, "name", strategy)
    cfg_json = to_jsonable(cfg)
    man = {
        "schema": MANIFEST_SCHEMA,
        "created_unix": time.time(),
        "created": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "git": git_revision(),
        "jax_version": jax.__version__,
        "numpy_version": np.__version__,
        "python_version": platform.python_version(),
        "platform": platform.platform(),
        "hostname": socket.gethostname(),
        **device_info(),
        "strategy": strategy_name,
        "scenario": scenario_name,
        "config": cfg_json,
        "config_hash": config_hash(cfg_json, to_jsonable(scenario),
                                   strategy_name),
    }
    if mesh is not None:
        man["mesh"] = {str(k): int(v) for k, v in dict(mesh.shape).items()}
    if extra:
        man.update(to_jsonable(extra))
    return man
