"""Alert rules over the live telemetry stream (DESIGN.md §Obs-live).

The paper gives us *reference envelopes*, not just metrics: Thm. 1
guarantees per-cluster O(1/T) convergence, and eq. (5) water-fills the
per-channel-use transmit power against an explicit budget.  A monitor
can therefore check a run against the theory *while it is in flight*
instead of eyeballing curves afterwards.  Each rule consumes the stream
records `repro.obs.stream.RoundStream` emits and produces structured
:class:`Alert` records — ``(rule, round, trajectory, value, threshold)``
— written back to the same sinks, so a tailed JSONL carries both the
telemetry and the judgments on it.

Rules (all per-trajectory, keyed by the record's ``(seed, snr_db)``):

* ``non_finite_loss``   — train/cluster loss went NaN/inf;
* ``consensus_drift``   — max ‖θ_c − θ̄‖ exceeded an absolute ceiling or
  blew up relative to its first observed value (divergence, the failure
  mode `flaky-clients` quarantine exists to contain);
* ``quarantine_rate``   — fraction of clients the divergence guard has
  quarantined (``fault_quarantined`` extra) crossed a threshold;
* ``power_budget``      — eq. (5): the CWFL per-channel-use transmit
  power ``power_budget_frac`` (Σ tx_power / P_total per use) exceeded
  its budget (tolerance ×1.05 for float slack);
* ``convergence_stall`` — fits the running loss history against the
  paper's envelope  loss(t) ≈ a + c/t  by least squares on the basis
  [1, 1/t] and alerts when (a) the latest loss sits far above the fit
  (relative to the trajectory's observed loss range — flat-but-converged
  runs stay silent) or (b) the fitted decay coefficient c is negative
  while the loss is *rising* — no O(1/T) behaviour at all.

Escalation: ``Monitor(abort_on_alert=True)`` (or a tuple of rule names)
raises ``should_abort`` once a matching alert fires; the engine's
checkpointed scan drivers poll it between segments and stop *after*
persisting the checkpoint — the run resumes exactly where it aborted.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Iterable, Optional, Sequence, Union

import numpy as np

ALERT_SCHEMA = "repro.obs.alert/v1"


@dataclasses.dataclass
class Alert:
    """One structured rule violation."""

    rule: str
    round: int
    trajectory: dict            # {"seed": int|None, "snr_db": float|None}
    value: float
    threshold: float
    message: str

    def to_record(self) -> dict:
        return {"type": "alert", "schema": ALERT_SCHEMA,
                **dataclasses.asdict(self)}


def _traj_key(rec: dict) -> tuple:
    return (rec.get("seed"), rec.get("snr_db"))


def _traj_tag(rec: dict) -> dict:
    return {"seed": rec.get("seed"), "snr_db": rec.get("snr_db")}


class AlertRule:
    """Base rule: stateful per trajectory, fed one stream record at a
    time (arrival order may interleave trajectories and — under the
    unordered mc tap — rounds; rules index state by the record's tags)."""

    name = "base"

    def observe(self, rec: dict) -> list[Alert]:  # pragma: no cover
        raise NotImplementedError

    def _alert(self, rec: dict, value, threshold, message: str) -> Alert:
        return Alert(rule=self.name, round=int(rec["round"]),
                     trajectory=_traj_tag(rec), value=float(value),
                     threshold=float(threshold), message=message)


class NonFiniteLossRule(AlertRule):
    """train_loss or any per-site cluster loss went NaN/±inf."""

    name = "non_finite_loss"

    def observe(self, rec: dict) -> list[Alert]:
        vals = [("train_loss", np.asarray(rec["train_loss"], np.float64))]
        tele = rec.get("telemetry") or {}
        if "cluster_loss" in tele:
            vals.append(("cluster_loss",
                         np.asarray(tele["cluster_loss"], np.float64)))
        out = []
        for label, v in vals:
            if not np.all(np.isfinite(v)):
                bad = float(np.asarray(v).ravel()[
                    int(np.argmin(np.isfinite(np.asarray(v).ravel())))])
                out.append(self._alert(
                    rec, bad, 0.0,
                    f"{label} is non-finite at round {rec['round']}"))
        return out


class ConsensusDriftRule(AlertRule):
    """max ‖θ_site − θ̄‖ over an absolute ceiling, or blown up by
    ``blowup``× relative to the trajectory's first observed drift."""

    name = "consensus_drift"

    def __init__(self, max_drift: float = 100.0, blowup: float = 50.0):
        self.max_drift = float(max_drift)
        self.blowup = float(blowup)
        self._baseline: dict[tuple, float] = {}

    def observe(self, rec: dict) -> list[Alert]:
        tele = rec.get("telemetry") or {}
        if "consensus_drift" not in tele:
            return []
        drift = float(np.max(np.asarray(tele["consensus_drift"],
                                        np.float64)))
        if not math.isfinite(drift):
            return []  # non_finite_loss covers NaN blowups
        key = _traj_key(rec)
        base = self._baseline.setdefault(key, drift)
        out = []
        if drift > self.max_drift:
            out.append(self._alert(
                rec, drift, self.max_drift,
                f"consensus drift {drift:.3g} over ceiling "
                f"{self.max_drift:.3g}"))
        elif base > 1e-9 and drift > self.blowup * base:
            out.append(self._alert(
                rec, drift, self.blowup * base,
                f"consensus drift {drift:.3g} is {drift / base:.1f}x its "
                f"round-1 baseline {base:.3g}"))
        return out


class QuarantineRateRule(AlertRule):
    """Divergence-guard quarantines (`repro.sim.faults`) exceed a
    fraction of the client population.  Silent when the run carries no
    fault plane (no ``fault_quarantined`` extra)."""

    name = "quarantine_rate"

    def __init__(self, max_rate: float = 0.5):
        self.max_rate = float(max_rate)

    def observe(self, rec: dict) -> list[Alert]:
        extras = (rec.get("telemetry") or {}).get("extras") or {}
        if "fault_quarantined" not in extras:
            return []
        quarantined = float(np.asarray(extras["fault_quarantined"]))
        alive = extras.get("fault_alive")
        if alive is not None and np.asarray(alive).ndim:
            total = float(np.asarray(alive).shape[-1])
        else:
            total = float(np.asarray(rec["telemetry"]["participants"])
                          + quarantined)
        if total <= 0:
            return []
        rate = quarantined / total
        if rate > self.max_rate:
            return [self._alert(
                rec, rate, self.max_rate,
                f"{int(quarantined)}/{int(total)} clients quarantined "
                f"({rate:.0%} > {self.max_rate:.0%})")]
        return []


class PowerBudgetRule(AlertRule):
    """eq. (5): per-channel-use transmit power over budget.  CWFL's
    telemetry extras report ``power_budget_frac`` = Σ_k tx_power_k /
    P_total per use; the water-filling solution keeps it ≤ 1, so any
    excursion past ``tol`` means the precoder broke its constraint."""

    name = "power_budget"

    def __init__(self, tol: float = 1.05):
        self.tol = float(tol)

    def observe(self, rec: dict) -> list[Alert]:
        extras = (rec.get("telemetry") or {}).get("extras") or {}
        if "power_budget_frac" not in extras:
            return []
        frac = float(np.max(np.asarray(extras["power_budget_frac"],
                                       np.float64)))
        if frac > self.tol:
            return [self._alert(
                rec, frac, self.tol,
                f"eq.(5) transmit power at {frac:.3f}x budget "
                f"(tol {self.tol:.2f})")]
        return []


class ConvergenceStallRule(AlertRule):
    """Fit loss(t) ≈ a + c/t (Thm. 1's O(1/T) envelope) over the
    trajectory's streamed history; alert when the run stopped tracking
    it.  Uses least squares on the basis [1, 1/t] (t 1-based), needs
    ``min_rounds`` points, and normalizes the residual by the observed
    loss range so converged-flat trajectories never fire."""

    name = "convergence_stall"

    def __init__(self, min_rounds: int = 6, rel_tol: float = 0.5,
                 min_range: float = 1e-4):
        self.min_rounds = int(min_rounds)
        self.rel_tol = float(rel_tol)
        self.min_range = float(min_range)
        self._hist: dict[tuple, dict[int, float]] = {}

    def observe(self, rec: dict) -> list[Alert]:
        key = _traj_key(rec)
        hist = self._hist.setdefault(key, {})
        hist[int(rec["round"])] = float(np.asarray(rec["train_loss"],
                                                   np.float64))
        if len(hist) < self.min_rounds:
            return []
        t = np.array(sorted(hist), np.float64)
        y = np.array([hist[int(k)] for k in t], np.float64)
        if not np.all(np.isfinite(y)):
            return []  # non_finite_loss owns that failure
        span = float(y.max() - y.min())
        if span < self.min_range:
            return []  # flat (converged or constant): no stall signal
        basis = np.stack([np.ones_like(t), 1.0 / t], axis=1)
        (a, c), *_ = np.linalg.lstsq(basis, y, rcond=None)
        fit_last = a + c / t[-1]
        resid = float(y[-1] - fit_last)
        out = []
        if resid > self.rel_tol * span:
            out.append(self._alert(
                rec, resid / span, self.rel_tol,
                f"loss {y[-1]:.4g} sits {resid / span:.2f}x the loss range "
                f"above its fitted a+c/t envelope (a={a:.4g}, c={c:.4g})"))
        elif c < 0 and y[-1] > y[0]:
            out.append(self._alert(
                rec, float(c), 0.0,
                f"no O(1/T) decay: fitted c={c:.4g} < 0 with loss rising "
                f"{y[0]:.4g} -> {y[-1]:.4g}"))
        return out


def default_rules(*, max_drift: float = 100.0, drift_blowup: float = 50.0,
                  max_quarantine_rate: float = 0.5,
                  power_tol: float = 1.05, stall_min_rounds: int = 6,
                  stall_rel_tol: float = 0.5) -> list[AlertRule]:
    """The standard rule set; thresholds are generous enough that the
    committed paper-static goldens stay silent (pinned by tests/CI)."""
    return [
        NonFiniteLossRule(),
        ConsensusDriftRule(max_drift=max_drift, blowup=drift_blowup),
        QuarantineRateRule(max_rate=max_quarantine_rate),
        PowerBudgetRule(tol=power_tol),
        ConvergenceStallRule(min_rounds=stall_min_rounds,
                             rel_tol=stall_rel_tol),
    ]


class Monitor:
    """Evaluates a rule set on every stream record; accumulates alerts;
    decides escalation.

    ``abort_on_alert``: ``False`` (observe only), ``True`` (any alert
    escalates) or an iterable of rule names.  The monitor itself never
    stops anything — `repro.sim.engine`'s checkpointed drivers poll
    ``should_abort`` between scan segments and perform the
    checkpoint-then-stop."""

    def __init__(self, rules: Optional[Sequence[AlertRule]] = None,
                 abort_on_alert: Union[bool, Iterable[str]] = False):
        self.rules = list(default_rules() if rules is None else rules)
        if isinstance(abort_on_alert, bool):
            self.abort_on_alert: Any = abort_on_alert
        else:
            self.abort_on_alert = frozenset(abort_on_alert)
        self.alerts: list[Alert] = []
        self._abort = False

    def observe(self, rec: dict) -> list[Alert]:
        fired: list[Alert] = []
        for rule in self.rules:
            try:
                fired.extend(rule.observe(rec))
            except Exception as e:  # a broken rule must not kill the run
                fired.append(Alert(
                    rule=f"{rule.name}!error", round=int(rec.get("round", 0)),
                    trajectory=_traj_tag(rec), value=float("nan"),
                    threshold=float("nan"), message=repr(e)))
        self.alerts.extend(fired)
        for a in fired:
            if self.abort_on_alert is True or (
                    not isinstance(self.abort_on_alert, bool)
                    and a.rule in self.abort_on_alert):
                self._abort = True
        return fired

    @property
    def should_abort(self) -> bool:
        return self._abort

    def summary(self) -> dict:
        by_rule: dict[str, int] = {}
        for a in self.alerts:
            by_rule[a.rule] = by_rule.get(a.rule, 0) + 1
        return {"alerts": len(self.alerts), "by_rule": by_rule,
                "aborted": self._abort}
