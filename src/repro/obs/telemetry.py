"""In-scan round telemetry (DESIGN.md §Obs).

The paper's claims are *trajectory* claims — O(1/T) convergence per
cluster, communication cost vs decentralized FL — but the engine's scan
only ever surfaced two scalars per round.  :class:`RoundTelemetry` is the
per-round observation pytree the `repro.sim.engine` / `repro.sim.sharded`
scan bodies emit when telemetry is enabled (a STATIC opt-in flag — the
telemetry-off trajectory is byte-identical to the untelemetered jaxpr):

* ``cluster_loss``      — per-aggregation-site mean client loss (a fresh
  full-shard eval on the post-local-training params — deterministic, and
  deliberately NOT the round's minibatch loss buffer, whose re-use would
  re-fuse the round's own mean and shift train_loss by ulps): (C,) for
  CWFL's clusters, (1,) global for server/decentralized strategies;
* ``participants``      — effective transmit-side participation after
  masking and forced-present rules (heads / the COTAF server);
* ``consensus_drift``   — ‖θ_c − θ̄‖ per site: how far the per-cluster
  (or per-node) models sit from the global consensus;
* ``channel_uses`` / ``cum_channel_uses`` / ``cum_symbols`` — the OTA
  channel-use ledger (`repro.obs.ledger`): MAC slots this round, the
  running slot total, and the running scalar-symbol total (slots × d);
* ``reclustered``       — 1.0 on rounds where the `lax.cond`-gated
  re-clustering fired;
* ``extras``            — strategy-specific internals from the
  ``Strategy.telemetry`` hook (CWFL: eq. (5) precode scales, water-filled
  P_k, per-channel-use transmit power vs the power budget, phase-1/2
  receiver-noise stds and the expected injected-noise energy; COTAF: the
  server index and its MAC equivalents; decentralized: graph occupancy).

Everything is pure jnp computed from intermediates the round body already
materializes (plus the one fresh loss eval above) — no extra RNG draws,
no host syncs, and no second consumer on any fusion-sensitive buffer —
so telemetry-on runs leave the ``train_loss``/``test_acc`` history
bit-for-bit unchanged (pinned by ``tests/test_obs.py``).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class RoundTelemetry(NamedTuple):
    """One round's observations.  A NamedTuple so it is automatically a
    pytree — it rides the scan's per-round outputs and vmaps over
    Monte-Carlo axes like any metric buffer."""

    cluster_loss: Any       # (C,) or (1,) mean client loss per site
    participants: Any       # () effective transmit-side count
    consensus_drift: Any    # (C,) or (1,) ‖θ_site − θ̄‖
    channel_uses: Any       # () MAC slots consumed this round
    cum_channel_uses: Any   # () running slot ledger
    cum_symbols: Any        # () running scalar-symbol ledger (slots × d)
    reclustered: Any        # () {0,1} re-cluster event fired
    extras: dict            # strategy-specific internals (scan-legal)


def init_ledger() -> dict:
    """Zeroed cumulative channel-use ledger for the scan carry."""
    return {"uses": jnp.zeros((), jnp.float32),
            "symbols": jnp.zeros((), jnp.float32)}


def per_client_dim(stacked) -> int:
    """d = dim(θ_k): scalars per client of a K-stacked pytree (static)."""
    return sum(int(np.prod(x.shape[1:])) for x in jax.tree.leaves(stacked))


def stacked_consensus_drift(stacked, consensus) -> jnp.ndarray:
    """(R,) ℓ₂ distance of each leading-axis row of ``stacked`` from the
    ``consensus`` pytree (one client/head/site per row)."""
    rows = jax.tree.leaves(stacked)[0].shape[0]
    sq = sum(
        jnp.sum(jnp.square(
            x.astype(jnp.float32).reshape(rows, -1)
            - c.astype(jnp.float32).reshape(-1)[None, :]), axis=1)
        for x, c in zip(jax.tree.leaves(stacked), jax.tree.leaves(consensus)))
    return jnp.sqrt(sq)


def build_round_telemetry(strategy, state, *, losses, stacked, new_stacked,
                          consensus, mask, num_clients: int,
                          num_clusters: int, ledger: dict,
                          reclustered=None, fault_extras=None):
    """Assemble one :class:`RoundTelemetry` from the round body's
    intermediates plus the `Strategy.telemetry` hook, and advance the
    cumulative channel-use ledger.

    Returns ``(telemetry, new_ledger)``.  ``state`` is the round's
    aggregation state (the per-round rebuild in dynamic scenarios, the
    offline state on the static path); ``stacked`` is the post-local-
    training / pre-sync parameter stack; ``reclustered`` is the
    `lax.cond` predicate of the re-clustering gate (``None`` when the
    scenario never reclusters); ``fault_extras`` is the fault plane's
    per-round event dict (`repro.sim.faults` — alive/tx_ok vectors, burst
    and blackout indicators, quarantine count), merged into ``extras``
    under ``fault_*`` keys so fault events ride the same scan output as
    every other observable (``None`` on fault-free builds — zero pytree
    change).
    """
    t = strategy.telemetry(state, losses=losses, stacked=stacked,
                           new_stacked=new_stacked, consensus=consensus,
                           mask=mask)
    extras = t.get("extras", {})
    if fault_extras is not None:
        extras = dict(extras)
        extras.update({f"fault_{k}": jnp.asarray(v, jnp.float32)
                       for k, v in fault_extras.items()})
    uses = jnp.asarray(
        strategy.channel_uses(num_clients, num_clusters=num_clusters,
                              participants=t["participants"]), jnp.float32)
    d = per_client_dim(stacked)
    new_ledger = {"uses": ledger["uses"] + uses,
                  "symbols": ledger["symbols"] + uses * d}
    tele = RoundTelemetry(
        cluster_loss=t["cluster_loss"],
        participants=t["participants"],
        consensus_drift=t["consensus_drift"],
        channel_uses=uses,
        cum_channel_uses=new_ledger["uses"],
        cum_symbols=new_ledger["symbols"],
        reclustered=(jnp.zeros((), jnp.float32) if reclustered is None
                     else jnp.asarray(reclustered, jnp.float32)),
        extras=extras,
    )
    return tele, new_ledger
