from repro.optim.optimizers import (
    Optimizer,
    sgd,
    sgd_momentum,
    adamw,
    constant_schedule,
    inverse_time_schedule,
    cosine_schedule,
)
