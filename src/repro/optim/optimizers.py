"""Minimal optax-free optimizer substrate (container has jax/numpy only).

The paper trains with plain SGD (lr 1e-3); its convergence theorem uses the
inverse-time schedule η_t = 2/(µ(γ+t)). Both are provided, plus momentum and
AdamW for the beyond-paper large-architecture training paths.

API mirrors optax: ``opt.init(params) -> state``;
``opt.update(grads, state, params) -> (updates, state)``; apply with
``jax.tree.map(lambda p, u: p + u, params, updates)``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


def constant_schedule(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def inverse_time_schedule(mu: float, gamma: float) -> Schedule:
    """Theorem 1's η_t = 2 / (µ (γ + t))."""
    return lambda step: 2.0 / (mu * (gamma + step))


def cosine_schedule(peak: float, total_steps: int, warmup: int = 0) -> Schedule:
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak * step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total_steps - warmup, 1),
                        0.0, 1.0)
        cos = peak * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return f


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[..., tuple]   # (grads, state, params) -> (updates, state)


class _SGDState(NamedTuple):
    step: jnp.ndarray


def sgd(lr: float | Schedule) -> Optimizer:
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        del params
        return _SGDState(step=jnp.zeros((), jnp.int32))

    def update(grads, state, params=None):
        del params
        eta = sched(state.step)
        updates = jax.tree.map(lambda g: (-eta * g).astype(g.dtype), grads)
        return updates, _SGDState(step=state.step + 1)

    return Optimizer(init, update)


class _MomState(NamedTuple):
    step: jnp.ndarray
    velocity: Any


def sgd_momentum(lr: float | Schedule, beta: float = 0.9) -> Optimizer:
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        return _MomState(step=jnp.zeros((), jnp.int32),
                         velocity=jax.tree.map(jnp.zeros_like, params))

    def update(grads, state, params=None):
        del params
        eta = sched(state.step)
        vel = jax.tree.map(lambda v, g: beta * v + g, state.velocity, grads)
        updates = jax.tree.map(lambda v: (-eta * v).astype(v.dtype), vel)
        return updates, _MomState(step=state.step + 1, velocity=vel)

    return Optimizer(init, update)


class _AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def adamw(lr: float | Schedule, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return _AdamWState(step=jnp.zeros((), jnp.int32),
                           mu=jax.tree.map(zeros, params),
                           nu=jax.tree.map(zeros, params))

    def update(grads, state, params):
        step = state.step + 1
        eta = sched(state.step)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)
        mu_hat = jax.tree.map(lambda m: m / (1 - b1 ** step), mu)
        nu_hat = jax.tree.map(lambda v: v / (1 - b2 ** step), nu)

        def upd(m, v, p):
            u = -eta * (m / (jnp.sqrt(v) + eps) + weight_decay *
                        p.astype(jnp.float32))
            return u.astype(p.dtype)

        updates = jax.tree.map(upd, mu_hat, nu_hat, params)
        return updates, _AdamWState(step=step, mu=mu, nu=nu)

    return Optimizer(init, update)
