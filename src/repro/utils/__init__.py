from repro.utils.jaxcompat import cost_analysis_dict
from repro.utils.pytree import (
    tree_add,
    tree_scale,
    tree_weighted_sum,
    tree_zeros_like,
    tree_l2_norm,
    tree_sq_norm,
    tree_add_noise,
    tree_size,
    tree_flatten_vector,
    tree_unflatten_vector,
)
