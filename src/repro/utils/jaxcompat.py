"""Small shims over jax API differences between the versions this repo
supports (0.4.x ... current)."""
from __future__ import annotations


def cost_analysis_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` returns a dict on current jax but a
    list of one per-partition dict on 0.4.x — normalize to a dict."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca
