"""Pytree utilities used throughout the framework.

All CWFL aggregation operators act on parameter/gradient *pytrees*; these
helpers keep the core algorithm readable and vectorization-friendly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


def tree_weighted_sum(trees, weights):
    """sum_i weights[i] * trees[i]. ``trees`` is a list of pytrees."""
    weights = jnp.asarray(weights)
    return jax.tree.map(
        lambda *leaves: sum(w * l for w, l in zip(weights, leaves)), *trees
    )


def tree_sq_norm(a):
    leaves = jax.tree.leaves(a)
    return sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)


def tree_l2_norm(a):
    return jnp.sqrt(tree_sq_norm(a))


def tree_size(a) -> int:
    """Total number of scalar parameters d = dim(theta)."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(a))


def tree_add_noise(a, key, sigma):
    """a + w, w ~ N(0, sigma^2 I_d), elementwise over every leaf."""
    leaves, treedef = jax.tree.flatten(a)
    keys = jax.random.split(key, len(leaves))
    noisy = [
        x + sigma * jax.random.normal(k, x.shape, dtype=x.dtype)
        for x, k in zip(leaves, keys)
    ]
    return jax.tree.unflatten(treedef, noisy)


def tree_flatten_vector(a):
    """Flatten a pytree into a single 1-D vector (for OTA transmission)."""
    leaves = jax.tree.leaves(a)
    return jnp.concatenate([jnp.ravel(x) for x in leaves], axis=0)


def tree_unflatten_vector(vec, like):
    """Inverse of :func:`tree_flatten_vector` given a template pytree."""
    leaves, treedef = jax.tree.flatten(like)
    out, off = [], 0
    for x in leaves:
        n = int(np.prod(x.shape))
        out.append(jnp.reshape(vec[off : off + n], x.shape).astype(x.dtype))
        off += n
    return jax.tree.unflatten(treedef, out)
