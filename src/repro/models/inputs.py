"""Model input construction: concrete batches (smoke tests/examples) and
ShapeDtypeStruct stand-ins (dry-run, no allocation).

A *batch* is a dict:
  tokens        (B, S_text) int32            — always present
  labels        (B, S_text) int32            — train only
  patch_embeds  (B, prefix, frontend_dim)    — vision_stub only
  frames        (B, encoder_seq, frontend_dim) — audio_stub only

For VLM archs the model prepends ``prefix_tokens`` projected patches, so
S_text = seq_len − prefix_tokens keeps the total sequence at seq_len.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig, InputShape


def text_len(cfg: ArchConfig, seq_len: int) -> int:
    if cfg.frontend == "vision_stub":
        return max(seq_len - cfg.prefix_tokens, 1)
    return seq_len


def train_batch_specs(cfg: ArchConfig, shape: InputShape):
    B, S = shape.global_batch, shape.seq_len
    st = text_len(cfg, S)
    specs = {
        "tokens": jax.ShapeDtypeStruct((B, st), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, st), jnp.int32),
    }
    if cfg.frontend == "vision_stub":
        specs["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.prefix_tokens, cfg.frontend_dim), cfg.cdtype)
    if cfg.frontend == "audio_stub":
        specs["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.frontend_dim), cfg.cdtype)
    return specs


def prefill_batch_specs(cfg: ArchConfig, shape: InputShape):
    specs = train_batch_specs(cfg, shape)
    specs.pop("labels")
    return specs


def make_batch(key, cfg: ArchConfig, seq_len: int, batch: int,
               kind: str = "train"):
    """Concrete random batch matching the specs above."""
    k1, k2, k3 = jax.random.split(key, 3)
    st = text_len(cfg, seq_len)
    out = {"tokens": jax.random.randint(k1, (batch, st), 0, cfg.vocab_size)}
    if kind == "train":
        out["labels"] = jax.random.randint(k2, (batch, st), 0, cfg.vocab_size)
    if cfg.frontend == "vision_stub":
        out["patch_embeds"] = jax.random.normal(
            k3, (batch, cfg.prefix_tokens, cfg.frontend_dim), cfg.cdtype)
    if cfg.frontend == "audio_stub":
        out["frames"] = jax.random.normal(
            k3, (batch, cfg.encoder_seq, cfg.frontend_dim), cfg.cdtype)
    return out
