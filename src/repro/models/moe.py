"""Token-choice top-k Mixture-of-Experts with sort-based capacity dispatch.

Memory is O(T·k + E·cap·d) — NOT the O(T·E·cap) of one-hot dispatch einsums,
which is intractable at 1 M tokens × 384 experts (kimi-k2). HLO FLOPs equal
*active* expert compute (plus router), keeping the roofline's
MODEL_FLOPS/HLO_FLOPs ratio honest.

Dispatch: flatten (token, choice) pairs, argsort by expert id, compute each
pair's rank within its expert group, drop ranks ≥ capacity, scatter into an
(E, cap, d) buffer, run the expert SwiGLU as a batched einsum, gather back
with router-probability combine weights.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def moe_init(key, d_model: int, d_ff: int, num_experts: int, dtype):
    k_r, k_g, k_u, k_d = jax.random.split(key, 4)
    e = num_experts
    return {
        "router": dense_init(k_r, d_model, e, jnp.float32),
        "w_gate": (jax.random.normal(k_g, (e, d_model, d_ff), jnp.float32)
                   / jnp.sqrt(d_model)).astype(dtype),
        "w_up": (jax.random.normal(k_u, (e, d_model, d_ff), jnp.float32)
                 / jnp.sqrt(d_model)).astype(dtype),
        "w_down": (jax.random.normal(k_d, (e, d_ff, d_model), jnp.float32)
                   / jnp.sqrt(d_ff)).astype(dtype),
    }


def _capacity(T: int, top_k: int, E: int, capacity_factor: float) -> int:
    """Expected load × factor, floored at min(T, 16) so that tiny batches
    (decode: T = B) are drop-free — a token loads an expert at most once,
    so cap ≥ T guarantees no drops regardless of routing skew."""
    return int(max(-(-T * top_k // E) * capacity_factor, min(T, 16), 1))


def _dispatch_local(x, top_e, top_p, E: int, cap: int):
    """Sort-based dispatch of ONE shard's tokens. x: (T, d); top_*: (T, k).
    Returns (buf: (E, cap, d), st, dst_e, dst_c, keepw)."""
    T, d = x.shape
    top_k = top_e.shape[-1]
    flat_e = top_e.reshape(-1)                                  # (T·k,)
    flat_t = jnp.arange(T * top_k) // top_k                     # token ids
    flat_p = top_p.reshape(-1)
    order = jnp.argsort(flat_e)
    se, st, sp = flat_e[order], flat_t[order], flat_p[order]
    group_start = jnp.searchsorted(se, jnp.arange(E))           # (E,)
    rank = jnp.arange(T * top_k) - group_start[se]
    keep = rank < cap
    dst_e = jnp.where(keep, se, E)                              # drop -> OOB
    dst_c = jnp.where(keep, rank, 0)
    buf = jnp.zeros((E + 1, cap, d), x.dtype)
    buf = buf.at[dst_e, dst_c].set(x[st])
    return buf[:E], st, dst_e, dst_c, (sp * keep)


def moe_apply(params, x, *, top_k: int, capacity_factor: float = 1.25,
              shards: int = 1, shard_axes=None):
    """x: (T, d) -> (y: (T, d), aux: load-balance loss scalar).

    ``shards``: dispatch locality factor — tokens are dispatched within
    T/shards groups (mapped onto the mesh data axis by the caller's input
    sharding). This keeps the argsort/rank bookkeeping *local to a shard*
    (a global sort over a distributed (T·k,) array forces replication, which
    is what makes one-big-sort MoE blow up at 1 M tokens × 384 experts);
    the expert einsum over the (shards, E, cap, d) buffer then lowers to the
    canonical all-to-all. Capacity is per-shard (cap_global/shards)."""
    T, d = x.shape
    E = params["router"].shape[-1]
    if T % shards != 0:
        shards = 1
    Tl = T // shards

    logits = x.astype(jnp.float32) @ params["router"]           # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, top_k)                  # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # Aux load-balance loss (Switch-style): E · Σ_e fraction_e · prob_e.
    frac = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e, E, dtype=jnp.float32), axis=1), axis=0)
    aux = E * jnp.sum(frac * jnp.mean(probs, axis=0))

    cap = _capacity(Tl, top_k, E, capacity_factor)

    # Explicit shardings (production mesh). The scheme mirrors production
    # expert-parallel MoE: dispatch locally per data shard, all-to-all the
    # slot buffers to EXPERT-sharded layout (E over data), run the expert
    # matmuls TP-sharded on ff (matching the (E→data, d, ff→model) weight
    # sharding so neither the forward nor the weight-grad einsum needs a
    # full gather), all-to-all back. Without these constraints GSPMD fully
    # replicates the O(T·k·d) buffers — 75+ GB/device at kimi-k2 scale.
    if shard_axes is not None:
        from jax.sharding import PartitionSpec as _P
        batch_ax, model_ax = shard_axes
        wsc = jax.lax.with_sharding_constraint
        c_tok = lambda t: wsc(t, _P(batch_ax, None, None))
        c_exp = lambda t: wsc(t, _P(batch_ax, None, None, None))
        c_ff = lambda t: wsc(t, _P(batch_ax, None, None, model_ax))
    else:
        c_tok = c_exp = c_ff = lambda t: t

    xs = c_tok(x.reshape(shards, Tl, d))
    buf, st, dst_e, dst_c, keepw = jax.vmap(
        lambda xl, te, tp: _dispatch_local(xl, te, tp, E, cap)
    )(xs, top_e.reshape(shards, Tl, top_k), top_p.reshape(shards, Tl, top_k))

    # all-to-all: (s→data, E, cap, d) -> (E→data, s, cap, d)
    buf_t = c_exp(jnp.transpose(buf, (1, 0, 2, 3)))             # (E,s,cap,d)

    # ---- expert SwiGLU (E expert-parallel, ff tensor-parallel) ---------
    g = c_ff(jax.nn.silu(jnp.einsum("escd,edf->escf", buf_t,
                                    params["w_gate"])))
    u = c_ff(jnp.einsum("escd,edf->escf", buf_t, params["w_up"]))
    h = c_exp(jnp.einsum("escf,efd->escd", g * u, params["w_down"]))
    # reverse all-to-all: back to (s→data, E, cap, d)
    h = jnp.transpose(h, (1, 0, 2, 3))
    if shard_axes is not None:
        from jax.sharding import PartitionSpec as _P
        h = jax.lax.with_sharding_constraint(
            h, _P(batch_ax, None, None, None))

    # ---- combine (local to each shard) ----------------------------------
    def combine_local(hl, st, dst_e, dst_c, keepw):
        vals = hl[dst_e.clip(0, E - 1), dst_c]                  # (Tl·k, d)
        w = keepw.astype(vals.dtype)[:, None]
        return jnp.zeros((Tl, d), vals.dtype).at[st].add(vals * w)

    y = c_tok(jax.vmap(combine_local)(h, st, dst_e, dst_c, keepw))
    return y.reshape(T, d).astype(x.dtype), aux
