"""Architecture configuration: a single dataclass describes every assigned
architecture (dense / MoE / hybrid SSM / xLSTM / VLM / audio enc-dec).

A model is a cycle of ``LayerSpec``s (the *pattern*) repeated
``num_layers / len(pattern)`` times; parameters for each pattern position are
stacked over repeats and the stack is scanned (`lax.scan`) so HLO size is
independent of depth. ``reduced()`` returns the ≤2-layer, d_model ≤ 512 smoke
variant required for CPU tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str = "attn"        # attn | mamba | mlstm | slstm
    window: int = 0            # sliding-window size for attn (0 = full)
    ffn: str = "dense"         # dense | moe | none


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str                      # dense|moe|hybrid|ssm|vlm|audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    head_dim: Optional[int] = None

    # MoE
    num_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_shards: int = 1                 # shard-local dispatch groups (= dp)

    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    softcap_attn: float = 0.0
    softcap_final: float = 0.0
    rope_theta: float = 10000.0

    # SSM (mamba)
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0                # 0 -> ceil(d_model / 16)

    # encoder-decoder (audio) / prefix (vlm)
    encoder_layers: int = 0
    encoder_seq: int = 0                # whisper: 1500 frames
    frontend: str = "none"              # none | audio_stub | vision_stub
    frontend_dim: int = 0               # embedding dim provided by the stub
    prefix_tokens: int = 0              # vlm: #patch embeddings prepended

    # numerics / structure
    norm: str = "rmsnorm"               # rmsnorm | layernorm
    tie_embeddings: bool = False
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    scan_layers: bool = True
    unroll_loops: bool = False          # cost-measurement mode (see roofline)
    attn_chunk: int = 512               # flash kv-block
    attn_gqa_repeat: bool = False       # §Perf 'gqarep' layout (see attention.py)
    ssm_chunk: int = 256
    mlstm_chunk: int = 256
    remat: bool = False                 # activation checkpoint each block
    # optional activation sharding constraint (axis names per (B, S, d) dim),
    # applied at block boundaries — Megatron-style activation sharding that
    # keeps saved remat inputs sharded over the model axis.
    act_spec: Optional[Tuple[Optional[str], ...]] = None

    # citation of the source model/paper for this config
    source: str = ""

    def __post_init__(self):
        if self.num_layers % len(self.pattern) != 0:
            raise ValueError(
                f"{self.name}: num_layers={self.num_layers} not divisible by "
                f"pattern length {len(self.pattern)}")

    # ---- derived ----
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def num_periods(self) -> int:
        return self.num_layers // len(self.pattern)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or -(-self.d_model // 16)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: ≤2 pattern periods (but full pattern), tiny
        dims (d_model ≤ 512, ≤ 4 experts), CPU-friendly."""
        period = len(self.pattern)
        d_model = min(self.d_model, 128)
        heads = min(self.num_heads, 4)
        kv = min(self.num_kv_heads, max(1, heads // 2))
        heads = (heads // kv) * kv  # keep divisibility
        moe = self.num_experts > 0
        return self.replace(
            num_layers=period if period > 2 else 2 * period,
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=None,
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            d_ff_expert=min(self.d_ff_expert, 128) if moe else 0,
            num_experts=min(self.num_experts, 4) if moe else 0,
            top_k=min(self.top_k, 2) if moe else 0,
            # Dropless capacity (cf = E/k) so decode-vs-forward consistency
            # tests are exact; production configs keep cf=1.25 (drops are an
            # inherent property of capacity-based token-choice MoE).
            capacity_factor=(min(self.num_experts, 4) / min(self.top_k, 2)
                             if moe else self.capacity_factor),
            vocab_size=min(self.vocab_size, 512),
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=min(self.encoder_seq, 16) if self.encoder_seq else 0,
            prefix_tokens=min(self.prefix_tokens, 8) if self.prefix_tokens else 0,
            frontend_dim=min(self.frontend_dim, 64) if self.frontend_dim else 0,
            attn_chunk=64,
            ssm_chunk=32,
            mlstm_chunk=32,
        )


# ---------------------------------------------------------------------------
# Input shapes (assigned): name -> (seq_len, global_batch, kind)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                            # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
