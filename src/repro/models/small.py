"""The paper's experiment models (§V): a 4-layer MLP (MNIST) and a 6-layer
CNN (CIFAR), pure-JAX init/apply pairs (NLL loss via log-softmax outputs).

MNIST net: 4 fully-connected layers with ReLU, log-softmax head.
CIFAR net: conv 3→64, 64→120, 120→200 (each followed by 2×2 max-pool) then
two FC layers — "6 layers" counting conv+fc — log-softmax head.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp


def _dense_init(key, d_in, d_out):
    k1, _ = jax.random.split(key)
    scale = jnp.sqrt(2.0 / d_in)
    return {"w": scale * jax.random.normal(k1, (d_in, d_out), jnp.float32),
            "b": jnp.zeros((d_out,), jnp.float32)}


def _conv_init(key, kh, kw, c_in, c_out):
    scale = jnp.sqrt(2.0 / (kh * kw * c_in))
    return {"w": scale * jax.random.normal(key, (kh, kw, c_in, c_out),
                                           jnp.float32),
            "b": jnp.zeros((c_out,), jnp.float32)}


def _conv(x, p):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def _maxpool2(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


# ---------------------------------------------------------------------------
# MNIST MLP (4 layers).
# ---------------------------------------------------------------------------

def make_mnist_mlp(input_hw=(28, 28, 1), hidden: Sequence[int] = (200, 100, 64),
                   num_classes: int = 10):
    d_in = input_hw[0] * input_hw[1] * input_hw[2]
    dims = [d_in, *hidden, num_classes]

    def init(key):
        keys = jax.random.split(key, len(dims) - 1)
        return {f"fc{i}": _dense_init(k, dims[i], dims[i + 1])
                for i, k in enumerate(keys)}

    def apply(params, x):
        h = x.reshape(x.shape[0], -1)
        n = len(dims) - 1
        for i in range(n):
            p = params[f"fc{i}"]
            h = h @ p["w"] + p["b"]
            if i < n - 1:
                h = jax.nn.relu(h)
        return jax.nn.log_softmax(h, axis=-1)

    return init, apply


# ---------------------------------------------------------------------------
# CIFAR CNN (6 layers: 3 conv + pools, 2 hidden fc + head).
# ---------------------------------------------------------------------------

def make_cifar_cnn(input_hw=(32, 32, 3), num_classes: int = 10):
    h, w, c = input_hw
    # three 2x2 pools: spatial /8
    flat = (h // 8) * (w // 8) * 200

    def init(key):
        k = jax.random.split(key, 6)
        return {
            "conv0": _conv_init(k[0], 3, 3, c, 64),
            "conv1": _conv_init(k[1], 3, 3, 64, 120),
            "conv2": _conv_init(k[2], 3, 3, 120, 200),
            "fc0": _dense_init(k[3], flat, 128),
            "fc1": _dense_init(k[4], 128, num_classes),
        }

    def apply(params, x):
        h = x
        for name in ("conv0", "conv1", "conv2"):
            h = jax.nn.relu(_conv(h, params[name]))
            h = _maxpool2(h)
        h = h.reshape(h.shape[0], -1)
        h = jax.nn.relu(h @ params["fc0"]["w"] + params["fc0"]["b"])
        h = h @ params["fc1"]["w"] + params["fc1"]["b"]
        return jax.nn.log_softmax(h, axis=-1)

    return init, apply


def nll_loss(log_probs: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Paper's NLL loss on log-softmax outputs."""
    return -jnp.mean(jnp.take_along_axis(log_probs, labels[:, None],
                                         axis=1)[:, 0])


def accuracy(log_probs: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean(jnp.argmax(log_probs, axis=-1) == labels)
