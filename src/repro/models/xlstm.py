"""xLSTM blocks: mLSTM (matrix-memory, chunkwise-parallel) and sLSTM
(scalar-memory, strictly sequential) — arXiv:2405.04517.

mLSTM recurrence (stabilized; stored state C̃ = C/exp(m)):
    m_t = max(logσ(f̃_t) + m_{t−1}, ĩ_t)
    C̃_t = exp(logσ(f̃_t)+m_{t−1}−m_t)·C̃_{t−1} + exp(ĩ_t−m_t)·k_t v_tᵀ
    ñ_t = … (same, with k_t)
    h_t = C̃_tᵀ q_t / max(|ñ_tᵀ q_t|, exp(−m_t))

The chunkwise form computes, inside a chunk with carry (C̃₀, ñ₀, m₀):
    F_t = Σ_{s≤t} logσ(f̃_s),  a_s = ĩ_s − F_s,
    g_t = max(m₀, max_{s≤t} a_s),  m_t = F_t + g_t,
    intra weight w_{ts} = exp(a_s − g_t)·[s ≤ t],  inter scale exp(m₀ − g_t),
which is exactly the recurrence unrolled (validated against it in tests).
Chunk loop is `lax.scan` (or python in unroll/cost mode); intra-chunk work is
dense (c×c) matmuls — MXU-friendly on TPU.

sLSTM has per-head recurrent weights R·h_{t−1} in every gate, so it cannot be
parallelized over time; it is an elementwise `lax.scan` (cheap: O(S·d·dh)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rmsnorm


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, d_model: int, num_heads: int, dtype, proj_factor: int = 2):
    d_up = proj_factor * d_model
    ks = jax.random.split(key, 8)
    return {
        "up_proj": dense_init(ks[0], d_model, 2 * d_up, dtype),
        "conv_w": (jax.random.normal(ks[1], (4, d_up), jnp.float32) / 2.0
                   ).astype(dtype),
        "conv_b": jnp.zeros((d_up,), dtype),
        "wq": dense_init(ks[2], d_up, d_up, dtype),
        "wk": dense_init(ks[3], d_up, d_up, dtype),
        "wv": dense_init(ks[4], d_up, d_up, dtype),
        "w_gates": dense_init(ks[5], d_up, 2 * num_heads, jnp.float32),
        "b_gates": jnp.concatenate([
            jnp.zeros((num_heads,), jnp.float32),          # input gate bias
            3.0 + jnp.arange(num_heads, dtype=jnp.float32)  # forget-gate bias
        ]),
        "head_norm": jnp.zeros((d_up,), jnp.float32),
        "down_proj": dense_init(ks[6], d_up, d_model, dtype),
    }


def _mlstm_chunk(q, k, v, i_raw, lf, carry):
    """One chunk. q,k,v: (B,c,nh,dh); i_raw,lf: (B,c,nh);
    carry = (C: (B,nh,dk,dv), n: (B,nh,dk), m: (B,nh))."""
    C0, n0, m0 = carry
    F = jnp.cumsum(lf, axis=1)                              # (B,c,nh)
    a = i_raw - F
    g = jnp.maximum(m0[:, None], jax.lax.cummax(a, axis=1))  # (B,c,nh)
    m_t = F + g

    # intra-chunk: w[t,s] = exp(a_s − g_t) for s ≤ t.
    w = jnp.exp(a[:, None, :, :] - g[:, :, None, :])        # (B,t,s,nh)
    c = w.shape[1]
    tri = jnp.tril(jnp.ones((c, c), bool))
    w = jnp.where(tri[None, :, :, None], w, 0.0)
    qk = jnp.einsum("bthd,bshd->btsh", q, k)                # (B,t,s,nh)
    num = jnp.einsum("btsh,bshd->bthd", qk * w, v)
    den = jnp.einsum("btsh,btsh->bth", qk, w)

    inter = jnp.exp(m0[:, None] - g)                        # (B,c,nh)
    num = num + inter[..., None] * jnp.einsum("bthd,bhde->bthe", q, C0)
    den = den + inter * jnp.einsum("bthd,bhd->bth", q, n0)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]

    # chunk-end state
    g_end = g[:, -1]                                        # (B,nh)
    m_end = F[:, -1] + g_end
    wk = jnp.exp(a - g_end[:, None])                        # (B,c,nh)
    decay = jnp.exp(m0 - g_end)
    C1 = decay[:, :, None, None] * C0 + jnp.einsum("bshd,bsh,bshe->bhde",
                                                   k, wk, v)
    n1 = decay[:, :, None] * n0 + jnp.einsum("bshd,bsh->bhd", k, wk)
    return h, (C1, n1, m_end)


def mlstm_cell(q, k, v, i_raw, f_raw, chunk: int, unroll: bool = False,
               state=None):
    """q,k,v: (B,S,nh,dh); gates (B,S,nh). Returns (h, state)."""
    B, S, nh, dh = q.shape
    q = q * (dh ** -0.5)
    nchunks = -(-S // chunk)
    pad = nchunks * chunk - S
    if pad:
        zpad = lambda x: jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))
        q, k, v = zpad(q), zpad(k), zpad(v)
        i_raw = jnp.pad(i_raw, ((0, 0), (0, pad), (0, 0)),
                        constant_values=-1e30)   # no input contribution
        f_raw = jnp.pad(f_raw, ((0, 0), (0, pad), (0, 0)),
                        constant_values=1e3)     # forget gate ≈ 1
    lf = jax.nn.log_sigmoid(f_raw)
    if state is None:
        state = (jnp.zeros((B, nh, dh, dh), jnp.float32),
                 jnp.zeros((B, nh, dh), jnp.float32),
                 jnp.full((B, nh), -1e30, jnp.float32))

    split = lambda x: jnp.moveaxis(
        x.reshape(B, nchunks, chunk, *x.shape[2:]), 1, 0)
    qs, ks_, vs, is_, lfs = map(split, (q.astype(jnp.float32),
                                        k.astype(jnp.float32),
                                        v.astype(jnp.float32), i_raw, lf))
    if unroll:
        hs = []
        for i in range(nchunks):
            h, state = _mlstm_chunk(qs[i], ks_[i], vs[i], is_[i], lfs[i], state)
            hs.append(h)
        h = jnp.concatenate(hs, axis=1)
    else:
        state, hs = jax.lax.scan(
            lambda st, args: tuple(reversed(_mlstm_chunk(*args, st))),
            state, (qs, ks_, vs, is_, lfs))
        h = jnp.moveaxis(hs, 0, 1).reshape(B, nchunks * chunk, nh, dh)
    if pad:
        h = h[:, :S]
    return h, state


def mlstm_step(q, k, v, i_raw, f_raw, state):
    """Exact single-token recurrence (decode + test oracle).
    q,k,v: (B,nh,dh); gates (B,nh); state as in mlstm_cell."""
    C, n, m = state
    dh = q.shape[-1]
    q = q.astype(jnp.float32) * (dh ** -0.5)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    lf = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(lf + m, i_raw)
    f_eff = jnp.exp(lf + m - m_new)
    i_eff = jnp.exp(i_raw - m_new)
    C = f_eff[..., None, None] * C + i_eff[..., None, None] * (
        k[..., :, None] * v[..., None, :])
    n = f_eff[..., None] * n + i_eff[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, C)
    den = jnp.einsum("bhd,bhd->bh", q, n)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    return h, (C, n, m_new)


def mlstm_apply(params, x, cfg, cache=None, unroll: bool = False):
    """Full mLSTM block mixer. x: (B, S, d_model)."""
    from repro.models.ssm import causal_conv  # shared depthwise conv
    B, S, _ = x.shape
    nh = cfg.num_heads
    up = x @ params["up_proj"]
    d_up = up.shape[-1] // 2
    xm, z = up[..., :d_up], up[..., d_up:]
    conv_state = None if cache is None else cache["conv"]
    xc, new_conv = causal_conv(xm, params["conv_w"], params["conv_b"],
                               conv_state)
    xc = jax.nn.silu(xc)
    dh = d_up // nh
    shp = (B, S, nh, dh)
    q = (xc @ params["wq"]).reshape(shp)
    k = (xc @ params["wk"]).reshape(shp)
    v = (xm @ params["wv"]).reshape(shp)
    gates = xc.astype(jnp.float32) @ params["w_gates"] + params["b_gates"]
    i_raw = gates[..., :nh]
    f_raw = gates[..., nh:]

    if cache is None:
        h, state = mlstm_cell(q, k, v, i_raw, f_raw, cfg.mlstm_chunk,
                              unroll=unroll)
    else:
        state = (cache["C"], cache["n"], cache["m"])
        if S == 1:
            h1, state = mlstm_step(q[:, 0], k[:, 0], v[:, 0],
                                   i_raw[:, 0], f_raw[:, 0], state)
            h = h1[:, None]
        else:
            h, state = mlstm_cell(q, k, v, i_raw, f_raw, cfg.mlstm_chunk,
                                  unroll=unroll, state=state)

    h = h.reshape(B, S, d_up).astype(x.dtype)
    h = rmsnorm(h.reshape(B, S, nh, dh),
                params["head_norm"].reshape(nh, dh)).reshape(B, S, d_up)
    out = (h * jax.nn.silu(z)) @ params["down_proj"]
    C, n, m = state
    return out, {"conv": new_conv, "C": C, "n": n, "m": m}


def mlstm_cache_spec(cfg, batch: int):
    d_up = 2 * cfg.d_model
    nh = cfg.num_heads
    dh = d_up // nh
    return {
        "conv": jax.ShapeDtypeStruct((batch, 3, d_up), cfg.cdtype),
        "C": jax.ShapeDtypeStruct((batch, nh, dh, dh), jnp.float32),
        "n": jax.ShapeDtypeStruct((batch, nh, dh), jnp.float32),
        "m": jax.ShapeDtypeStruct((batch, nh), jnp.float32),
    }


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, d_model: int, num_heads: int, dtype):
    ks = jax.random.split(key, 4)
    dh = d_model // num_heads
    return {
        "w_in": dense_init(ks[0], d_model, 4 * d_model, dtype),
        "b_in": jnp.concatenate([
            jnp.zeros((2 * d_model,), jnp.float32),            # z, i
            jnp.full((d_model,), 3.0, jnp.float32),            # f bias
            jnp.zeros((d_model,), jnp.float32),                # o
        ]),
        "r": (jax.random.normal(ks[1], (4, num_heads, dh, dh), jnp.float32)
              / jnp.sqrt(dh)).astype(dtype),
        "head_norm": jnp.zeros((d_model,), jnp.float32),
        "out_proj": dense_init(ks[2], d_model, d_model, dtype),
    }


def slstm_step(params, xw, state, num_heads: int):
    """xw: precomputed x @ w_in + b for one step, (B, 4*d).
    state: (c, n, m, h) each (B, d). Returns (h_out, state)."""
    c, n, m, h = state
    B, d4 = xw.shape
    d = d4 // 4
    dh = d // num_heads
    hh = h.reshape(B, num_heads, dh)
    rec = jnp.einsum("bhd,ghde->gbhe", hh, params["r"].astype(jnp.float32))
    rec = rec.reshape(4, B, d)
    z_raw = xw[:, :d] + rec[0]
    i_raw = xw[:, d:2 * d] + rec[1]
    f_raw = xw[:, 2 * d:3 * d] + rec[2]
    o_raw = xw[:, 3 * d:] + rec[3]

    lf = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(lf + m, i_raw)
    i_eff = jnp.exp(i_raw - m_new)
    f_eff = jnp.exp(lf + m - m_new)
    c = f_eff * c + i_eff * jnp.tanh(z_raw)
    n = f_eff * n + i_eff
    h_new = jax.nn.sigmoid(o_raw) * c / jnp.maximum(n, 1e-6)
    return h_new, (c, n, m_new, h_new)


def slstm_apply(params, x, cfg, cache=None, unroll: bool = False):
    """sLSTM block mixer: sequential scan over time. x: (B, S, d)."""
    del unroll  # inherently sequential; counted analytically in the roofline
    B, S, d = x.shape
    nh = cfg.num_heads
    xw = (x.astype(jnp.float32) @ params["w_in"].astype(jnp.float32)
          + params["b_in"])                                # (B, S, 4d)
    if cache is None:
        zeros = jnp.zeros((B, d), jnp.float32)
        state = (zeros, zeros, jnp.full((B, d), -1e30, jnp.float32), zeros)
    else:
        state = (cache["c"], cache["n"], cache["m"], cache["h"])

    def step(st, xw_t):
        h, st = slstm_step(params, xw_t, st, nh)
        return st, h

    state, hs = jax.lax.scan(step, state, jnp.moveaxis(xw, 1, 0))
    h = jnp.moveaxis(hs, 0, 1)                             # (B, S, d)
    h = rmsnorm(h.reshape(B, S, nh, d // nh),
                params["head_norm"].reshape(nh, d // nh)).reshape(B, S, d)
    out = h.astype(x.dtype) @ params["out_proj"]
    c, n, m, hst = state
    return out, {"c": c, "n": n, "m": m, "h": hst}


def slstm_cache_spec(cfg, batch: int):
    d = cfg.d_model
    f32 = jnp.float32
    return {k: jax.ShapeDtypeStruct((batch, d), f32)
            for k in ("c", "n", "m", "h")}
