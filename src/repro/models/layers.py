"""Shared neural layers: norms, RoPE, SwiGLU, embeddings, softcap."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_init(key, d_in, d_out, dtype, scale: float | None = None):
    s = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    return (s * jax.random.normal(key, (d_in, d_out), jnp.float32)).astype(dtype)


def rmsnorm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layernorm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def norm(x, scale, kind: str):
    return rmsnorm(x, scale) if kind == "rmsnorm" else layernorm(x, scale)


def softcap(x, cap: float):
    """Gemma-2 style logit soft-capping: cap · tanh(x / cap)."""
    if cap <= 0.0:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


def rope(x, positions, theta: float = 10000.0):
    """Rotary position embedding. x: (..., S, H, D), positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(angles)[..., :, None, :]                       # (..., S, 1, half)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    dt = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1).astype(dt)


def swiglu_init(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def swiglu(params, x):
    g = jax.nn.silu(x @ params["w_gate"])
    h = g * (x @ params["w_up"])
    return h @ params["w_down"]
