from repro.models.small import (
    make_mnist_mlp,
    make_cifar_cnn,
    nll_loss,
    accuracy,
)
