"""Mamba-style selective SSM (S6) with chunked parallel scan.

Chunking rationale (DESIGN.md §4): a full-sequence associative scan would
materialize (B, S, d_inner, d_state) discretized transition tensors — TB-scale
at 32 k tokens. We scan sequentially over chunks (`lax.scan`, or a python loop
in unroll/cost-measurement mode) and run `associative_scan` only inside a
chunk, so transient memory is O(B · chunk · d_inner · d_state).

Decode is the exact single-step recurrence on (conv_state, ssm_state).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def mamba_init(key, d_model: int, d_inner: int, d_state: int, d_conv: int,
               dt_rank: int, dtype):
    ks = jax.random.split(key, 7)
    # S4D-real initialization for A.
    a = jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32)[None, :],
                 (d_inner, 1))
    return {
        "in_proj": dense_init(ks[0], d_model, 2 * d_inner, dtype),
        "conv_w": (jax.random.normal(ks[1], (d_conv, d_inner), jnp.float32)
                   / jnp.sqrt(d_conv)).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "x_proj": dense_init(ks[2], d_inner, dt_rank + 2 * d_state, dtype),
        "dt_proj": dense_init(ks[3], dt_rank, d_inner, dtype),
        "dt_bias": jnp.log(jnp.expm1(  # softplus^{-1}(dt) for dt ~ U[1e-3, 0.1]
            jax.random.uniform(ks[4], (d_inner,), jnp.float32,
                               1e-3, 1e-1))).astype(jnp.float32),
        "A_log": jnp.log(a),
        "D": jnp.ones((d_inner,), jnp.float32),
        "out_proj": dense_init(ks[5], d_inner, d_model, dtype),
    }


def _ssm_coeffs(params, xz, d_state: int, dt_rank: int, valid=None):
    """Per-token discretized coefficients from the post-conv activations.

    xz: (B, L, d_inner) -> dA: (B, L, d_inner, N), dBu: same, C: (B, L, N).
    ``valid``: optional (L,) bool — padded steps get identity transitions
    (dA=1, dBu=0) so they cannot decay the carried state.
    """
    proj = xz @ params["x_proj"]                       # (B, L, r + 2N)
    dt_raw = proj[..., :dt_rank]
    Bc = proj[..., dt_rank:dt_rank + d_state].astype(jnp.float32)
    Cc = proj[..., dt_rank + d_state:].astype(jnp.float32)
    dt = jax.nn.softplus(
        (dt_raw @ params["dt_proj"]).astype(jnp.float32) + params["dt_bias"])
    if valid is not None:
        dt = dt * valid[None, :, None]
    A = -jnp.exp(params["A_log"])                      # (d_inner, N)
    dA = jnp.exp(dt[..., None] * A)                    # (B, L, d_inner, N)
    dBu = (dt * xz.astype(jnp.float32))[..., None] * Bc[..., None, :]
    return dA, dBu, Cc


def selective_scan(params, xz, d_state: int, dt_rank: int, chunk: int,
                   unroll: bool = False, h0=None):
    """Chunked selective scan. xz: (B, L, d_inner) post-conv-activation.

    Returns (y: (B, L, d_inner) float32, h_final: (B, d_inner, N)).
    """
    B, L, d_inner = xz.shape
    nchunks = -(-L // chunk)
    pad = nchunks * chunk - L
    if pad:
        xz = jnp.pad(xz, ((0, 0), (0, pad), (0, 0)))
    if h0 is None:
        h0 = jnp.zeros((B, d_inner, d_state), jnp.float32)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    def run_chunk(h, args):
        xc, vc = args
        # xc: (B, chunk, d_inner); vc: (chunk,) validity
        dA, dBu, Cc = _ssm_coeffs(params, xc, d_state, dt_rank, valid=vc)
        A_cum, B_cum = jax.lax.associative_scan(combine, (dA, dBu), axis=1)
        h_t = A_cum * h[:, None] + B_cum                # (B, c, d_inner, N)
        y = jnp.einsum("bcdn,bcn->bcd", h_t, Cc)
        y = y + params["D"] * xc.astype(jnp.float32)
        return h_t[:, -1], y

    xcs = xz.reshape(B, nchunks, chunk, d_inner)
    valid = (jnp.arange(nchunks * chunk) < L).reshape(nchunks, chunk)
    if unroll:
        h, ys = h0, []
        for i in range(nchunks):
            h, y = run_chunk(h, (xcs[:, i], valid[i]))
            ys.append(y)
        y = jnp.concatenate(ys, axis=1)
    else:
        h, ys = jax.lax.scan(run_chunk, h0,
                             (jnp.moveaxis(xcs, 1, 0), valid))
        y = jnp.moveaxis(ys, 0, 1).reshape(B, nchunks * chunk, d_inner)
    if pad:
        y = y[:, :L]
    return y, h


def causal_conv(xz, conv_w, conv_b, conv_state=None):
    """Depthwise causal conv over time. xz: (B, L, d_inner); kernel (K, d)."""
    K = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xz.shape[0], K - 1, xz.shape[2]), xz.dtype)
    else:
        pad = conv_state.astype(xz.dtype)
    xp = jnp.concatenate([pad, xz], axis=1)            # (B, L+K-1, d)
    out = sum(xp[:, i:i + xz.shape[1]] * conv_w[i] for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else pad
    return out + conv_b, new_state


def mamba_apply(params, x, cfg, cache=None, unroll: bool = False):
    """Full Mamba block mixer. x: (B, L, d_model).

    cache: None (train/prefill from scratch) or dict(conv, h) for decode.
    Returns (y: (B, L, d_model), new_cache).
    """
    d_inner = cfg.d_inner
    xz_in = x @ params["in_proj"]                      # (B, L, 2*d_inner)
    xin, z = xz_in[..., :d_inner], xz_in[..., d_inner:]
    conv_state = None if cache is None else cache["conv"]
    xc, new_conv = causal_conv(xin, params["conv_w"], params["conv_b"],
                               conv_state)
    xc = jax.nn.silu(xc)
    h0 = None if cache is None else cache["h"]
    y, h = selective_scan(params, xc, cfg.ssm_state, cfg.dt_rank,
                          cfg.ssm_chunk, unroll=unroll, h0=h0)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = y @ params["out_proj"]
    return out, {"conv": new_conv, "h": h}


def mamba_cache_spec(cfg, batch: int):
    """ShapeDtypeStructs of the decode cache (for input_specs)."""
    return {
        "conv": jax.ShapeDtypeStruct((batch, cfg.ssm_conv - 1, cfg.d_inner),
                                     cfg.cdtype),
        "h": jax.ShapeDtypeStruct((batch, cfg.d_inner, cfg.ssm_state),
                                  jnp.float32),
    }
