"""Model assembly: pattern-cycled blocks, scan-over-layers, enc-dec & VLM.

Layout: ``cfg.pattern`` is a tuple of LayerSpecs cycled ``num_periods`` times.
Parameters for pattern position i are stacked over periods:
``params["layers"][f"b{i}"]`` has leaves of shape (num_periods, ...) and the
period dimension is scanned (HLO size independent of depth). Python-loop mode
(`scan_layers=False`) unrolls for the roofline cost measurement.

Entry points (all pure):
  init_params(key, cfg)
  forward(params, batch, cfg)            -> (logits, aux)        [train]
  prefill(params, batch, cfg)            -> (last_logits, caches)
  decode_step(params, token, caches, pos, cfg) -> (logits, caches)
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.attention import (decode_attention, decode_attention_delta,
                                    flash_attention)
from repro.models.config import ArchConfig, LayerSpec
from repro.models.layers import dense_init, norm, rope, softcap, swiglu, swiglu_init
from repro.models.moe import moe_apply, moe_init
from repro.models.ssm import mamba_apply, mamba_cache_spec, mamba_init
from repro.models.xlstm import (mlstm_apply, mlstm_cache_spec, mlstm_init,
                                slstm_apply, slstm_cache_spec, slstm_init)


# ---------------------------------------------------------------------------
# Attention sub-module.
# ---------------------------------------------------------------------------

def attn_init(key, cfg: ArchConfig, cross: bool = False):
    ks = jax.random.split(key, 6)
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    p = {
        "wq": dense_init(ks[0], d, H * hd, cfg.pdtype),
        "wk": dense_init(ks[1], d, KV * hd, cfg.pdtype),
        "wv": dense_init(ks[2], d, KV * hd, cfg.pdtype),
        "wo": dense_init(ks[3], H * hd, d, cfg.pdtype),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((H * hd,), cfg.pdtype)
        p["bk"] = jnp.zeros((KV * hd,), cfg.pdtype)
        p["bv"] = jnp.zeros((KV * hd,), cfg.pdtype)
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.zeros((hd,), jnp.float32)
        p["k_norm"] = jnp.zeros((hd,), jnp.float32)
    return p


def _qkv(p, x, cfg, positions, use_rope=True):
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    if "q_norm" in p:
        from repro.models.layers import rmsnorm
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    if use_rope and positions is not None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def self_attn_apply(p, x, cfg: ArchConfig, spec: LayerSpec, *,
                    positions, cache=None, causal=True, return_cache=False):
    """Self-attention. train: cache=None; prefill: return_cache=True;
    decode: cache = {k, v} with scalar ``pos`` handled by the caller via
    positions (= filled with pos) and cache writes."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, x, cfg, positions)
    if cache is None:
        o = flash_attention(q, k, v, causal=causal, window=spec.window,
                            cap=cfg.softcap_attn, block=cfg.attn_chunk,
                            unroll=cfg.unroll_loops,
                            gqa_repeat=cfg.attn_gqa_repeat)
        new_cache = {"k": k, "v": v} if return_cache else None
    else:
        # Paged-style decode (DESIGN.md §Perf): the cache is READ-ONLY and
        # does NOT contain the current token; its K/V are merged analytically
        # and returned as a delta for the serving engine to write. This keeps
        # the serve step's outputs O(1) in cache size (a full-cache output
        # contract costs 2-3x the cache in scan/copy buffers).
        pos = positions[0, 0]                      # scalar current position
        W = cache["k"].shape[1]
        if spec.window > 0 and W <= spec.window:
            # ring buffer holding the last W positions (excluding current);
            # the slot the engine will overwrite (pos % W = position pos−W)
            # is already outside the window.
            idx = jnp.arange(W)
            valid = (idx < pos) & (idx != pos % W)
            o = decode_attention_delta(
                q, cache["k"], cache["v"], k, v, pos, window=0,
                kv_valid=valid, cap=cfg.softcap_attn, block=cfg.attn_chunk,
                unroll=cfg.unroll_loops, gqa_repeat=cfg.attn_gqa_repeat)
        else:
            o = decode_attention_delta(
                q, cache["k"], cache["v"], k, v, pos, window=spec.window,
                cap=cfg.softcap_attn, block=cfg.attn_chunk,
                unroll=cfg.unroll_loops, gqa_repeat=cfg.attn_gqa_repeat)
        new_cache = {"k_new": k.astype(cache["k"].dtype),
                     "v_new": v.astype(cache["v"].dtype)}
    o = o.reshape(B, S, cfg.num_heads * cfg.hd)
    return o @ p["wo"], new_cache


def cross_attn_apply(p, x, cfg: ArchConfig, enc_kv):
    """Cross-attention to precomputed encoder K/V (whisper decoder)."""
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    o = flash_attention(q, enc_kv["k"], enc_kv["v"], causal=False,
                        cap=0.0, block=cfg.attn_chunk,
                        unroll=cfg.unroll_loops)
    return o.reshape(B, S, H * hd) @ p["wo"]


def encoder_kv(p, enc_out, cfg):
    """Precompute cross-attention K/V from encoder output (B, T, d)."""
    B, T, _ = enc_out.shape
    KV, hd = cfg.num_kv_heads, cfg.hd
    return {"k": (enc_out @ p["wk"]).reshape(B, T, KV, hd),
            "v": (enc_out @ p["wv"]).reshape(B, T, KV, hd)}


# ---------------------------------------------------------------------------
# Block = norm + mixer (+ cross-attn) (+ norm + ffn), all pre-norm residual.
# ---------------------------------------------------------------------------

def block_init(key, cfg: ArchConfig, spec: LayerSpec, cross: bool = False):
    ks = jax.random.split(key, 5)
    d = cfg.d_model
    p: dict[str, Any] = {"ln1": jnp.zeros((d,), jnp.float32)}
    if spec.mixer == "attn":
        p["attn"] = attn_init(ks[0], cfg)
    elif spec.mixer == "mamba":
        p["mamba"] = mamba_init(ks[0], d, cfg.d_inner, cfg.ssm_state,
                                cfg.ssm_conv, cfg.dt_rank, cfg.pdtype)
    elif spec.mixer == "mlstm":
        p["mlstm"] = mlstm_init(ks[0], d, cfg.num_heads, cfg.pdtype)
    elif spec.mixer == "slstm":
        p["slstm"] = slstm_init(ks[0], d, cfg.num_heads, cfg.pdtype)
    else:
        raise ValueError(f"unknown mixer {spec.mixer}")
    if cross:
        p["ln_x"] = jnp.zeros((d,), jnp.float32)
        p["xattn"] = attn_init(ks[1], cfg, cross=True)
    if spec.ffn == "dense":
        p["ln2"] = jnp.zeros((d,), jnp.float32)
        p["ffn"] = swiglu_init(ks[2], d, cfg.d_ff, cfg.pdtype)
    elif spec.ffn == "moe":
        p["ln2"] = jnp.zeros((d,), jnp.float32)
        p["moe"] = moe_init(ks[2], d, cfg.d_ff_expert, cfg.num_experts,
                            cfg.pdtype)
    return p


def block_cache_spec(cfg: ArchConfig, spec: LayerSpec, batch: int,
                     cache_len: int):
    """ShapeDtypeStruct pytree of this block's decode cache."""
    if spec.mixer == "attn":
        W = min(cache_len, spec.window) if spec.window > 0 else cache_len
        kv = jax.ShapeDtypeStruct((batch, W, cfg.num_kv_heads, cfg.hd),
                                  cfg.cdtype)
        return {"k": kv, "v": kv}
    if spec.mixer == "mamba":
        return mamba_cache_spec(cfg, batch)
    if spec.mixer == "mlstm":
        return mlstm_cache_spec(cfg, batch)
    if spec.mixer == "slstm":
        return slstm_cache_spec(cfg, batch)
    raise ValueError(spec.mixer)


def block_apply(p, x, cfg: ArchConfig, spec: LayerSpec, *, positions,
                cache=None, enc_kv=None, return_cache=False):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = norm(x, p["ln1"], cfg.norm)
    if spec.mixer == "attn":
        mixer_cache = None if cache is None else cache["mixer"]
        y, new_mixer = self_attn_apply(p["attn"], h, cfg, spec,
                                       positions=positions, cache=mixer_cache,
                                       return_cache=return_cache)
    elif spec.mixer == "mamba":
        y, new_mixer = mamba_apply(p["mamba"], h, cfg,
                                   None if cache is None else cache["mixer"],
                                   unroll=cfg.unroll_loops)
        if not return_cache and cache is None:
            new_mixer = None
    elif spec.mixer == "mlstm":
        y, new_mixer = mlstm_apply(p["mlstm"], h, cfg,
                                   None if cache is None else cache["mixer"],
                                   unroll=cfg.unroll_loops)
        if not return_cache and cache is None:
            new_mixer = None
    else:  # slstm
        y, new_mixer = slstm_apply(p["slstm"], h, cfg,
                                   None if cache is None else cache["mixer"])
        if not return_cache and cache is None:
            new_mixer = None
    x = x + y

    if enc_kv is not None and "xattn" in p:
        h = norm(x, p["ln_x"], cfg.norm)
        x = x + cross_attn_apply(p["xattn"], h, cfg, enc_kv)

    if spec.ffn == "dense":
        h = norm(x, p["ln2"], cfg.norm)
        x = x + swiglu(p["ffn"], h)
    elif spec.ffn == "moe":
        h = norm(x, p["ln2"], cfg.norm)
        B, S, d = h.shape
        shard_axes = None
        if cfg.act_spec is not None and cfg.moe_shards > 1:
            shard_axes = (cfg.act_spec[0], cfg.act_spec[-1])
        y, aux = moe_apply(p["moe"], h.reshape(B * S, d), top_k=cfg.top_k,
                           capacity_factor=cfg.capacity_factor,
                           shards=cfg.moe_shards, shard_axes=shard_axes)
        x = x + y.reshape(B, S, d)

    new_cache = None
    if new_mixer is not None:
        new_cache = {"mixer": new_mixer}
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Layer stack: scan over periods (or python loop in unroll mode).
# ---------------------------------------------------------------------------

def stack_init(key, cfg: ArchConfig, cross: bool = False):
    period = len(cfg.pattern)
    out = {}
    for i, spec in enumerate(cfg.pattern):
        keys = jax.random.split(jax.random.fold_in(key, i), cfg.num_periods)
        out[f"b{i}"] = jax.vmap(
            lambda k: block_init(k, cfg, spec, cross=cross))(keys)
    return out


def stack_apply(layers, x, cfg: ArchConfig, *, positions, caches=None,
                enc_kv=None, return_cache=False, cross: bool = False):
    """Apply all layers. caches: pytree with leading period axis per b{i}.

    Returns (x, new_caches, aux_total).
    """
    period = len(cfg.pattern)

    def one_period(x, period_params, period_caches):
        if cfg.act_spec is not None:
            from jax.sharding import PartitionSpec as _P
            x = jax.lax.with_sharding_constraint(x, _P(*cfg.act_spec))
        aux_sum = jnp.zeros((), jnp.float32)
        new_caches = {}
        for i, spec in enumerate(cfg.pattern):
            c = None if period_caches is None else period_caches[f"b{i}"]
            x, nc, aux = block_apply(
                period_params[f"b{i}"], x, cfg, spec, positions=positions,
                cache=c, enc_kv=enc_kv, return_cache=return_cache)
            aux_sum = aux_sum + aux
            if nc is not None:
                new_caches[f"b{i}"] = nc
        return x, (new_caches if new_caches else None), aux_sum

    if cfg.remat:
        one_period = jax.checkpoint(one_period)

    if cfg.scan_layers and cfg.num_periods > 1:
        def body(carry, xs):
            x, aux = carry
            pp, pc = xs
            x, nc, aux_p = one_period(x, pp, pc)
            return (x, aux + aux_p), nc

        (x, aux), new_caches = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), (layers, caches))
        return x, new_caches, aux
    else:
        aux_total = jnp.zeros((), jnp.float32)
        all_new = []
        for pidx in range(cfg.num_periods):
            pp = jax.tree.map(lambda a: a[pidx], layers)
            pc = (None if caches is None
                  else jax.tree.map(lambda a: a[pidx], caches))
            x, nc, aux_p = one_period(x, pp, pc)
            aux_total = aux_total + aux_p
            all_new.append(nc)
        new_caches = None
        if all_new and all_new[0] is not None:
            new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *all_new)
        return x, new_caches, aux_total


# ---------------------------------------------------------------------------
# Full model.
# ---------------------------------------------------------------------------

def init_params(key, cfg: ArchConfig):
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    params: dict[str, Any] = {
        "embed": (jax.random.normal(ks[0], (cfg.vocab_size, d), jnp.float32)
                  * (d ** -0.5)).astype(cfg.pdtype),
        "layers": stack_init(ks[1], cfg, cross=cfg.encoder_layers > 0),
        "final_norm": jnp.zeros((d,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[2], d, cfg.vocab_size, cfg.pdtype)
    if cfg.frontend == "vision_stub":
        params["projector"] = {
            "w1": dense_init(ks[3], cfg.frontend_dim, d, cfg.pdtype),
            "w2": dense_init(ks[4], d, d, cfg.pdtype),
        }
    if cfg.frontend == "audio_stub":
        enc_cfg = cfg.replace(num_layers=cfg.encoder_layers,
                              pattern=(LayerSpec("attn", 0, "dense"),))
        params["encoder"] = {
            "in_proj": dense_init(ks[3], cfg.frontend_dim, d, cfg.pdtype),
            "layers": stack_init(ks[5], enc_cfg),
            "final_norm": jnp.zeros((d,), jnp.float32),
        }
    return params


def _frontend_prefix(params, batch, cfg):
    """VLM: project patch embeddings into d_model prefix tokens."""
    pe = batch["patch_embeds"]
    h = jax.nn.gelu(pe.astype(cfg.cdtype) @ params["projector"]["w1"])
    return h @ params["projector"]["w2"]


def _encode_audio(params, batch, cfg):
    """Whisper encoder over stub frame embeddings (B, T_enc, frontend_dim)."""
    enc = params["encoder"]
    frames = batch["frames"].astype(cfg.cdtype)
    h = frames @ enc["in_proj"]
    T = h.shape[1]
    pos = jnp.arange(T)[None, :]
    enc_cfg = cfg.replace(num_layers=cfg.encoder_layers,
                          pattern=(LayerSpec("attn", 0, "dense"),))
    # Non-causal full attention encoder.
    def enc_block(p, x):
        x, _, _ = block_apply(p, x, enc_cfg, enc_cfg.pattern[0],
                              positions=pos)
        return x
    # note: encoder self-attn must be bidirectional -> custom path
    h2 = h
    layers = enc["layers"]
    for pidx in range(enc_cfg.num_periods):
        pp = jax.tree.map(lambda a: a[pidx], layers)["b0"]
        hh = norm(h2, pp["ln1"], cfg.norm)
        y, _ = self_attn_apply(pp["attn"], hh, enc_cfg, enc_cfg.pattern[0],
                               positions=pos, causal=False)
        h2 = h2 + y
        hh = norm(h2, pp["ln2"], cfg.norm)
        h2 = h2 + swiglu(pp["ffn"], hh)
    return norm(h2, enc["final_norm"], cfg.norm)


def _embed_tokens(params, tokens, cfg):
    return params["embed"].astype(cfg.cdtype)[tokens]


def _lm_logits(params, x, cfg):
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(cfg.cdtype)
    logits = x @ head
    return softcap(logits, cfg.softcap_final)


def _assemble_inputs(params, batch, cfg):
    """Token embeddings (+ VLM prefix), encoder output if any."""
    x = _embed_tokens(params, batch["tokens"], cfg)
    enc_kv_src = None
    if cfg.frontend == "vision_stub":
        prefix = _frontend_prefix(params, batch, cfg)
        x = jnp.concatenate([prefix.astype(x.dtype), x], axis=1)
    if cfg.frontend == "audio_stub":
        enc_kv_src = _encode_audio(params, batch, cfg)
    return x, enc_kv_src


def _first_cross_params(params, cfg):
    """Cross-attn K/V projections live in each decoder block; encoder K/V are
    computed per block inside stack (kv differ per layer). For simplicity and
    compile-size we compute enc K/V once from block b0's projections and share
    them across layers (weight-shared cross-attention)."""
    b0 = jax.tree.map(lambda a: a[0], params["layers"]["b0"])
    return b0["xattn"]


def forward(params, batch, cfg: ArchConfig):
    """Training forward: full-sequence logits. Returns (logits, aux)."""
    x, enc_out = _assemble_inputs(params, batch, cfg)
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]
    enc_kv = None
    if enc_out is not None:
        enc_kv = encoder_kv(_first_cross_params(params, cfg), enc_out, cfg)
    x, _, aux = stack_apply(params["layers"], x, cfg, positions=positions,
                            enc_kv=enc_kv)
    x = norm(x, params["final_norm"], cfg.norm)
    return _lm_logits(params, x, cfg), aux


def prefill(params, batch, cfg: ArchConfig):
    """Prefill: forward over the prompt, returning last-position logits and
    the full decode cache."""
    x, enc_out = _assemble_inputs(params, batch, cfg)
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]
    enc_kv = None
    if enc_out is not None:
        enc_kv = encoder_kv(_first_cross_params(params, cfg), enc_out, cfg)
    x, caches, aux = stack_apply(params["layers"], x, cfg,
                                 positions=positions, enc_kv=enc_kv,
                                 return_cache=True)
    x = norm(x, params["final_norm"], cfg.norm)
    logits = _lm_logits(params, x[:, -1:], cfg)
    return logits, caches


def decode_step(params, token, caches, pos, cfg: ArchConfig, enc_kv=None):
    """One decode step. token: (B, 1) int32; pos: scalar int32 (current
    write position = number of tokens already in the cache)."""
    x = _embed_tokens(params, token, cfg)
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    x, new_caches, _ = stack_apply(params["layers"], x, cfg,
                                   positions=positions, caches=caches,
                                   enc_kv=enc_kv, return_cache=True)
    x = norm(x, params["final_norm"], cfg.norm)
    return _lm_logits(params, x, cfg), new_caches


def decode_cache_specs(cfg: ArchConfig, batch: int, cache_len: int):
    """Stacked ShapeDtypeStruct cache pytree for the dry-run serve step."""
    out = {}
    for i, spec in enumerate(cfg.pattern):
        one = block_cache_spec(cfg, spec, batch, cache_len)
        out[f"b{i}"] = {"mixer": jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((cfg.num_periods,) + s.shape,
                                           s.dtype), one)}
    return out


def count_params(cfg: ArchConfig) -> int:
    """Analytic parameter count (no allocation)."""
    shapes = jax.eval_shape(lambda k: init_params(k, cfg),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    import numpy as np
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(shapes)))


def count_active_params(cfg: ArchConfig) -> int:
    """Active params per token (MoE: top_k of num_experts experts)."""
    total = count_params(cfg)
    if cfg.num_experts == 0:
        return total
    shapes = jax.eval_shape(lambda k: init_params(k, cfg),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    import numpy as np
    expert = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        if any(k in ("w_gate", "w_up", "w_down") for k in keys) and \
           any(k == "moe" for k in keys):
            expert += int(np.prod(leaf.shape))
    active_expert = expert * cfg.top_k // max(cfg.num_experts, 1)
    return total - expert + active_expert
