"""GQA attention: chunked-flash (online-softmax over KV blocks) + decode path.

The chunked implementation never materializes the (Sq × Skv) score matrix —
required for 32 k-token prefill on the production mesh (a full score tensor
would be tens of GB per device). It is also the pure-jnp oracle for the
Pallas `flash_attention` kernel (same blocking, see repro/kernels).

Supports: causal masking, sliding windows (Gemma-2 local layers / 500 k
serving variants), logit soft-capping, grouped KV heads, decode-with-cache.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import softcap as _softcap

NEG_INF = -1e30


def _block_scores(q, kb, cap):
    """q: (B, Sq, KV, G, D), kb: (B, bk, KV, D) -> (B, Sq, KV, G, bk)."""
    s = jnp.einsum("bqkgd,bskd->bqkgs", q.astype(jnp.float32),
                   kb.astype(jnp.float32))
    if cap > 0.0:
        s = cap * jnp.tanh(s / cap)
    return s


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    cap: float = 0.0, q_offset=0,
                    kv_valid: Optional[jnp.ndarray] = None,
                    block: int = 512, unroll: bool = False,
                    return_stats: bool = False, gqa_repeat: bool = False):
    """Online-softmax attention over KV blocks.

    Args:
      q: (B, Sq, H, D); k, v: (B, Skv, KV, D) with H = KV·G.
      causal: mask k_pos > q_pos (+q_offset).
      window: if >0, also mask k_pos ≤ q_pos − window (sliding window).
      cap: attention logit softcap (Gemma-2).
      q_offset: absolute position of q[0] (decode: current cache length).
      kv_valid: optional (Skv,) or (B, Skv) boolean validity mask of the cache.
      block: KV block size; unroll: python-loop the blocks (cost measurement).
    Returns:
      (B, Sq, H, D) in q.dtype.
    """
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    if gqa_repeat and G > 1:
        # §Perf 'gqarep': expand KV heads to H up front instead of grouping
        # q into (KV, G, D). The 5-D grouped layout splits a model-sharded
        # head dim across (KV, G), which GSPMD can only reshard by full
        # rematerialization (per-layer replication copies). Repeating K/V
        # keeps the head dim intact (H divisible by the model axis for most
        # archs) at the cost of G× larger K/V blocks in VMEM.
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
        KV, G = H, 1
    qg = (q * (D ** -0.5)).reshape(B, Sq, KV, G, D)

    nb = -(-Skv // block)
    pad = nb * block - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if kv_valid is not None:
            kv_valid = jnp.pad(kv_valid, [(0, 0)] * (kv_valid.ndim - 1) + [(0, pad)])

    q_pos = q_offset + jnp.arange(Sq)

    def one_block(i, carry):
        m, l, acc = carry
        kb = jax.lax.dynamic_slice_in_dim(k, i * block, block, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, i * block, block, axis=1)
        s = _block_scores(qg, kb, cap)                     # (B,Sq,KV,G,bk)
        k_pos = i * block + jnp.arange(block)
        # Skv is the pre-pad key count: padded tail positions are invalid.
        mask = k_pos[None, :] < Skv
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        if window > 0:
            mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
        mask_b = mask[None, :, None, None, :]              # (1,Sq,1,1,bk)
        if kv_valid is not None:
            kvb = jax.lax.dynamic_slice_in_dim(kv_valid, i * block, block,
                                               axis=-1)
            if kvb.ndim == 1:
                kvb = kvb[None, None, None, None, :]
            else:                                          # (B, bk)
                kvb = kvb[:, None, None, None, :]
            mask_b = mask_b & kvb
        s = jnp.where(mask_b, s, NEG_INF)

        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bqkgs,bskd->bqkgd", p, vb.astype(jnp.float32))
        acc_new = acc * corr[..., None] + pv
        return m_new, l_new, acc_new

    m0 = jnp.full((B, Sq, KV, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, KV, G), jnp.float32)
    acc0 = jnp.zeros((B, Sq, KV, G, D), jnp.float32)

    if unroll:
        carry = (m0, l0, acc0)
        for i in range(nb):
            carry = one_block(i, carry)
        m, l, acc = carry
    else:
        m, l, acc = jax.lax.fori_loop(0, nb, one_block, (m0, l0, acc0))

    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = out.reshape(B, Sq, H, D).astype(q.dtype)
    if return_stats:
        return out, m.reshape(B, Sq, H), l.reshape(B, Sq, H)
    return out


def decode_attention(q, k_cache, v_cache, pos, *, window: int = 0,
                     cap: float = 0.0, block: int = 512,
                     unroll: bool = False):
    """One-token attention against a (possibly over-allocated) KV cache.

    q: (B, 1, H, D); caches: (B, S_cache, KV, D); ``pos``: (scalar) number of
    valid cache entries — the new token attends to cache[0:pos] (+ itself,
    which the caller has already written at index pos−… by convention we
    assume the caller wrote the new k/v at position pos, so valid = pos+1).
    """
    Skv = k_cache.shape[1]
    valid = jnp.arange(Skv) <= pos
    return flash_attention(q, k_cache, v_cache, causal=False, window=window,
                           cap=cap, q_offset=pos, kv_valid=valid,
                           block=block, unroll=unroll)


def decode_attention_delta(q, k_cache, v_cache, k_new, v_new, pos, *,
                           window: int = 0, cap: float = 0.0,
                           kv_valid: Optional[jnp.ndarray] = None,
                           block: int = 512, unroll: bool = False,
                           gqa_repeat: bool = False):
    """Paged-style decode: the cache is READ-ONLY (does not contain the new
    token); the new token's K/V are merged analytically via online-softmax
    statistics. This keeps the serve step's outputs O(1) in cache size — the
    serving engine owns the cache writes (DESIGN.md §Perf).

    q: (B, 1, H, D); caches: (B, S, KV, D); k_new/v_new: (B, 1, KV, D);
    ``pos``: number of valid cache entries (cache[0:pos] attended).
    """
    B, _, H, D = q.shape
    Skv = k_cache.shape[1]
    KV = k_cache.shape[2]
    G = H // KV
    if kv_valid is None:
        kv_valid = jnp.arange(Skv) < pos          # exclusive: new token separate
    out_c, m_c, l_c = flash_attention(
        q, k_cache, v_cache, causal=False, window=window, cap=cap,
        q_offset=pos, kv_valid=kv_valid, block=block, unroll=unroll,
        return_stats=True, gqa_repeat=gqa_repeat)
    # self-attention score of the new token
    qg = (q.astype(jnp.float32) * (D ** -0.5)).reshape(B, 1, KV, G, D)
    s_new = jnp.einsum("bqkgd,bqkd->bqkg", qg,
                       k_new.astype(jnp.float32))        # (B,1,KV,G)
    if cap > 0.0:
        s_new = cap * jnp.tanh(s_new / cap)
    s_new = s_new.reshape(B, 1, H)
    m_f = jnp.maximum(m_c, s_new)
    corr_c = jnp.exp(m_c - m_f)
    p_new = jnp.exp(s_new - m_f)
    l_f = l_c * corr_c + p_new
    v_rep = jnp.repeat(v_new.astype(jnp.float32), G, axis=2)  # (B,1,H,D)
    num = (out_c.astype(jnp.float32) * (l_c * corr_c)[..., None]
           + p_new[..., None] * v_rep)
    return (num / jnp.maximum(l_f[..., None], 1e-30)).astype(q.dtype)
