"""CWFL: the paper's 3-phase clustered over-the-air aggregation (Algorithm 1).

Operates on *stacked client pytrees*: every leaf has a leading axis K (one
slice per client).  The same operator is reused by:

* the CPU-scale paper reproduction (vmap-ed clients, parameter aggregation,
  packaged for the scenario engine as `repro.strategies.CWFLStrategy`),
* the production-mesh integration (gradient aggregation inside shard_map,
  `repro.dist.ota_collectives`), and
* the Pallas `ota_aggregate` kernel (flat-vector fast path).

Phases (paper §IV):
  1. intra-cluster OTA MAC:  θ̃_c = Σ_{k∈K_c} p_k θ_k + θ_{v,c} + w̃_c   (eq. 8)
  2. inter-head consensus:   θ̄_c = Σ_j W(c,j)(θ̃_j + ṽ_j) + θ̃_c        (eq. 9 / lemma 2)
  3. broadcast:              θ_k ← θ̄_{c(k)}  (error-free downlink)

`normalize=True` renormalizes each phase's weights into a convex combination
(see DESIGN.md §1: the literal equations have total weight > 1 and diverge
when iterated; normalization is required to reproduce the paper's Table I).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import channel as ch
from repro.core import clustering as cl
from repro.core.topology import Topology


@dataclasses.dataclass(frozen=True)
class CWFLConfig:
    num_clusters: int = 3
    normalize: bool = True          # convex-combination mode (see DESIGN.md)
    snr_db: Optional[float] = None  # override topology noise to hit overall SNR
    stationary: bool = True         # paper: channel fixed across rounds


@dataclasses.dataclass(frozen=True)
class CWFLState:
    """Everything the aggregation operator needs, precomputed offline."""

    plan: cl.ClusterPlan
    client_power: jnp.ndarray        # (K,) water-filled P_k, Σ = P
    total_power: float               # P
    head_noise_std: jnp.ndarray      # (C,) σ_c (receiver AWGN std, phase 1)
    consensus_noise_std: jnp.ndarray  # (C,) σ used on head→head links (phase 2)
    mix: jnp.ndarray                 # (C, C) consensus weights W (diag = 0)

    @property
    def num_clients(self) -> int:
        return int(self.client_power.shape[0])

    @property
    def num_clusters(self) -> int:
        return self.plan.num_clusters


# Pytree registration (total_power is static aux data — always the
# topology's concrete python float) so states can live in scan carries and
# jit arguments inside the scenario engine.
jax.tree_util.register_pytree_node(
    CWFLState,
    lambda s: ((s.plan, s.client_power, s.head_noise_std,
                s.consensus_noise_std, s.mix), s.total_power),
    lambda aux, c: CWFLState(plan=c[0], client_power=c[1], total_power=aux,
                             head_noise_std=c[2], consensus_noise_std=c[3],
                             mix=c[4]))


def setup(topology: Topology, cfg: CWFLConfig, key: jax.Array) -> CWFLState:
    """Offline phase: cluster on SNR, water-fill power, build W (paper §IV)."""
    plan = cl.make_cluster_plan(topology.link_snr, topology.adjacency,
                                cfg.num_clusters, key)
    noise_var = topology.noise_var
    if cfg.snr_db is not None:
        noise_var = ch.snr_db_to_noise_var(topology.total_power, cfg.snr_db)
    return state_from_plan(plan, topology.link_gain,
                           float(topology.total_power), noise_var)


def state_from_plan(plan: cl.ClusterPlan, link_gain: jnp.ndarray,
                    total_power: float, noise_var,
                    csi_perturb: Optional[jnp.ndarray] = None) -> CWFLState:
    """Water-fill power and budget noise for a *given* cluster plan.

    This is the per-channel-realization half of :func:`setup`, split out so
    the scenario engine (`repro.sim`) can rebuild the round state from a
    time-varying ``link_gain`` inside a ``lax.scan`` body — everything here
    is pure jnp and traces cleanly (``noise_var`` may be a traced scalar,
    e.g. a vmapped SNR-sweep axis).

    ``csi_perturb``: optional (K,) multiplicative factor on the effective
    water-filling gains — models imperfect CSI at power-allocation time
    (the *true* channel still carries the signal; only the allocator is
    misinformed).
    """
    K = link_gain.shape[0]

    # Effective member→head channel gains; heads use their mean head→head gain.
    head_of = plan.heads[plan.assignment]                    # (K,)
    gain_to_head = jnp.abs(link_gain[jnp.arange(K), head_of]) ** 2
    head_rows = jnp.abs(link_gain[plan.heads][:, plan.heads]) ** 2
    mean_h2h = head_rows.sum() / jnp.maximum(
        plan.num_clusters * (plan.num_clusters - 1), 1)
    is_head = plan.head_mask > 0
    eff_gain = jnp.where(is_head, mean_h2h, gain_to_head) / noise_var
    if csi_perturb is not None:
        eff_gain = eff_gain * csi_perturb

    client_power = ch.water_filling(eff_gain, total_power)
    sigma = jnp.sqrt(noise_var)
    head_noise_std = jnp.full((plan.num_clusters,), 1.0, jnp.float32) * sigma
    consensus_noise_std = jnp.full((plan.num_clusters,), 1.0,
                                   jnp.float32) * sigma
    mix = cl.consensus_weights(plan.cluster_snr)
    return CWFLState(plan=plan, client_power=client_power,
                     total_power=total_power,
                     head_noise_std=head_noise_std,
                     consensus_noise_std=consensus_noise_std, mix=mix)


# ---------------------------------------------------------------------------
# Stacked-pytree linear algebra helpers.
# ---------------------------------------------------------------------------

def _per_client_sq_norm(stacked) -> jnp.ndarray:
    """(K,) squared parameter norm per client of a K-stacked pytree."""
    leaves = jax.tree.leaves(stacked)
    return sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)).reshape(x.shape[0], -1), axis=1)
        for x in leaves
    )


def _per_client_dim(stacked) -> int:
    """d = dim(θ_k): number of scalars per client (= channel uses per sync)."""
    return sum(int(np.prod(x.shape[1:])) for x in jax.tree.leaves(stacked))


def per_client_mean_sq(stacked) -> jnp.ndarray:
    """(K,) per-channel-use signal power ‖θ_k‖²/d — eq. (5)'s estimator."""
    return _per_client_sq_norm(stacked) / max(_per_client_dim(stacked), 1)


def precode_scale(state: CWFLState, mean_sq_norm: jnp.ndarray) -> jnp.ndarray:
    """Eq. (5) amplitude scale per client (channel.precode_amplitude), with
    heads exempt — they are virtual clients whose local contribution never
    crosses the channel."""
    pre = ch.precode_amplitude(state.client_power, mean_sq_norm)
    return jnp.where(state.plan.head_mask > 0, 1.0, pre)


def _mix_rows(weights: jnp.ndarray, stacked, key: Optional[jax.Array],
              noise_std_per_row: Optional[jnp.ndarray]):
    """out[r] = Σ_k weights[r, k] · stacked[k]  (+ N(0, std_r²) per element).

    ``weights``: (R, K); every leaf of ``stacked`` has leading axis K; the
    result's leaves have leading axis R.
    """
    leaves, treedef = jax.tree.flatten(stacked)
    n = len(leaves)
    keys = jax.random.split(key, n) if key is not None else [None] * n
    out = []
    for x, k in zip(leaves, keys):
        flat = x.reshape(x.shape[0], -1).astype(jnp.float32)
        y = weights @ flat                                       # (R, prod)
        if k is not None and noise_std_per_row is not None:
            y = y + noise_std_per_row[:, None] * jax.random.normal(
                k, y.shape, dtype=y.dtype)
        out.append(y.reshape((weights.shape[0],) + x.shape[1:]).astype(x.dtype))
    return jax.tree.unflatten(treedef, out)


def cwfl_round_auto(*args, **kwargs):
    """Lazy forward to :func:`repro.kernels.cwfl_round.cwfl_round_auto`
    so the core layer doesn't pay the pallas import unless the flat fast
    path actually runs (and tests can monkeypatch the route here)."""
    from repro.kernels.cwfl_round import cwfl_round_auto as impl
    return impl(*args, **kwargs)


def _flat_leaf_noise(key: jax.Array, leaves, rows: int,
                     std_per_row: jnp.ndarray) -> jnp.ndarray:
    """The exact noise stream :func:`_mix_rows` would add — same per-leaf
    key splits, same (rows, prod) draw shapes — concatenated into one
    ``(rows, d)`` matrix so the flat fast path is bit-compatible with the
    per-leaf reference path."""
    keys = jax.random.split(key, len(leaves))
    cols = [
        std_per_row[:, None] * jax.random.normal(
            k, (rows, int(np.prod(x.shape[1:]))), jnp.float32)
        for x, k in zip(leaves, keys)
    ]
    return jnp.concatenate(cols, axis=1)


# ---------------------------------------------------------------------------
# The aggregation operator (Algorithm 1, sync step t ∈ H).
# ---------------------------------------------------------------------------

def phase1_weights(state: CWFLState) -> jnp.ndarray:
    """(C, K) OTA aggregation weights: p_k = sqrt(P_k/P) for members, 1 for the
    head's virtual client (noiseless local contribution)."""
    p = jnp.sqrt(state.client_power / state.total_power)         # (K,)
    w_k = jnp.where(state.plan.head_mask > 0, 1.0, p)
    return state.plan.membership * w_k[None, :]


def phase2_weights(state: CWFLState, normalize: bool = True, live=None):
    """(C, C) inter-head mix ``B = W + I`` and (C,) equivalent per-receiver
    noise std κ_c = sqrt(Σ_j W(c,j)²)·σ̃ (eq. 9 / lemma 2 with independent
    per-link noise; the self-link is local and noiseless).  With
    ``normalize`` both are renormalized by the row sums (convex-combination
    mode, DESIGN.md §1).

    ``live``: optional (C,) {0,1} cluster-liveness (fault scenarios,
    DESIGN.md §Faults) — a *dead* cluster (every member crashed, head
    included) transmits nothing in phase 2, so its B̃ *column* is zeroed
    before the row renormalization: each surviving head mixes the live
    heads only, with its noise renormalized by the (smaller) live row
    mass.  Dead *rows* are kept as that live-only mix — the receiver math
    is virtual (nobody is home to run it), but it keeps θ̄_dead a sane
    convex combination of live clusters so the consensus mean stays
    well-defined.  ``live=None`` is byte-identical to the faultless path.
    """
    b = state.mix + jnp.eye(state.num_clusters)
    eff_std2 = state.consensus_noise_std / jnp.sqrt(state.total_power)
    if live is None:
        kappa = jnp.sqrt(jnp.sum(state.mix ** 2, axis=1)) * eff_std2
        if normalize:
            row_sums = b.sum(axis=1, keepdims=True)
            b = b / row_sums
            kappa = kappa / row_sums[:, 0]
        return b, kappa
    lv = live.astype(jnp.float32)
    b = b * lv[None, :]
    kappa = jnp.sqrt(jnp.sum((state.mix * lv[None, :]) ** 2,
                             axis=1)) * eff_std2
    if normalize:
        # All-dead plans leave all-zero rows; guard the division (the
        # engine's all-masked sync-skip discards the output anyway).
        row_sums = jnp.maximum(b.sum(axis=1, keepdims=True), 1e-12)
        b = b / row_sums
        kappa = kappa / row_sums[:, 0]
    return b, kappa


def participation_weights(state: CWFLState,
                          mask: Optional[jnp.ndarray],
                          alive: Optional[jnp.ndarray] = None
                          ) -> Optional[jnp.ndarray]:
    """(K,) effective participation for one round, or ``None`` if unmasked.

    Cluster-heads are forced present: they are the phase-1 *receivers* and
    the phase-2 consensus endpoints, so a head dropping out would kill its
    whole cluster (an all-zero Ã row whose renormalization then amplifies
    the receiver noise unboundedly).  A mask entry of 0 on a head is
    therefore silently ignored — an app-level absence (scheduling) does
    not take the *receiver* offline.

    A true head outage is different: ``alive`` (fault scenarios,
    `repro.sim.faults`) is the (K,) {0,1} node-up vector of the Markov
    crash chain, and a *crashed* head is NOT forced present — the
    ``on_head_failure`` handoff re-elects a surviving head first, so the
    only way a forced-present entry dies is when its whole cluster
    crashed (handled by ``round_coefficients``'s dead-row guard).
    ``alive=None`` keeps the faultless behavior byte-identical.
    """
    if mask is None and alive is None:
        return None
    forced = state.plan.head_mask
    if alive is not None:
        forced = forced * alive.astype(jnp.float32)
    m = (jnp.ones_like(forced) if mask is None
         else mask.astype(jnp.float32))
    return jnp.where(forced > 0, 1.0, m)


def round_coefficients(state: CWFLState, stacked_params=None,
                       normalize: bool = True, precode: bool = True,
                       mask: Optional[jnp.ndarray] = None,
                       mean_sq: Optional[jnp.ndarray] = None,
                       alive: Optional[jnp.ndarray] = None):
    """The complete weight set of one sync round: phase-1 amplitudes Ã
    (precoded + renormalized), the effective phase-1 receiver noise std,
    the consensus mix B̃ with its equivalent noise std κ, and the phase-3
    downlink matrix — everything :func:`repro.kernels.cwfl_round.cwfl_round`
    needs besides the signals and the pre-drawn noise.

    ``stacked_params`` may be any K-stacked pytree — a flat ``(K, d)``
    matrix included — and is required when ``precode=True`` (the eq. 5
    amplitude clip is estimated from the transmitted signal's power).

    ``mask``: optional (K,) {0,1} per-round participation (DESIGN.md §Sim).
    Absent clients get a zero column in Ã *before* the row renormalization,
    so they neither transmit power nor bias the OTA sum — each head's
    superposition becomes a convex combination of the *present* members
    only, and the effective receiver noise is renormalized by the same
    (smaller) row sum, i.e. fewer participants ⇒ noisier round, exactly
    the physical behaviour.  Heads are always present (see
    :func:`participation_weights`).  ``mask=None`` and an all-ones mask
    produce bit-identical coefficients.

    ``alive``: optional (K,) {0,1} node-up vector (fault scenarios,
    DESIGN.md §Faults).  Crashed heads lose their forced-present status
    (:func:`participation_weights`), and a cluster whose *every* member
    crashed becomes a dead row: its phase-1 weights AND its receiver
    noise are zeroed (θ̃_dead ≡ 0 instead of the ~1e12× noise
    amplification an all-zero row's renormalization would produce), and
    its phase-2 column is pruned from B̃ (:func:`phase2_weights`).
    ``alive=None`` adds zero traced ops.
    """
    A = phase1_weights(state)                                    # (C, K)
    part = participation_weights(state, mask, alive=alive)
    if part is not None:
        A = A * part[None, :]

    # eq. (5): clients whose per-symbol power E‖θ‖²/d exceeds 1 scale down
    # to meet E‖x‖² ≤ P_k (precode_scale — per channel use, DESIGN.md §1).
    # ``mean_sq`` lets a caller that cannot see the whole stacked pytree
    # (a client-sharded rank, `repro.sim.sharded`) supply the globally
    # gathered (K,) per-channel-use power instead.
    if precode:
        if mean_sq is None:
            if stacked_params is None:
                raise ValueError(
                    "precode=True needs stacked_params (or a precomputed "
                    "mean_sq): the eq. (5) amplitude clip is estimated "
                    "from the transmitted signals' power")
            mean_sq = per_client_mean_sq(stacked_params)
        A = A * precode_scale(state, mean_sq)[None, :]

    # Receiver scaling (eq. 8): AWGN std σ_c/sqrt(P); with normalization
    # both weights and noise are divided by the phase-1 row sums.
    eff_std1 = state.head_noise_std / jnp.sqrt(state.total_power)
    if alive is None:
        if normalize:
            rows = jnp.maximum(A.sum(axis=1, keepdims=True), 1e-12)
            A = A / rows
            eff_std1 = eff_std1 / rows[:, 0]
        B, kappa = phase2_weights(state, normalize)
        return A, eff_std1, B, kappa, state.plan.membership.T
    # Fault path: a cluster with zero present transmit mass (everyone
    # crashed/silenced, head included) is DEAD — zero its weights and its
    # noise rather than divide both by the 1e-12 floor.
    raw = A.sum(axis=1, keepdims=True)
    dead = raw[:, 0] <= 0.0
    if normalize:
        rows = jnp.maximum(raw, 1e-12)
        A = A / rows
        eff_std1 = eff_std1 / rows[:, 0]
    A = jnp.where(dead[:, None], 0.0, A)
    eff_std1 = jnp.where(dead, 0.0, eff_std1)
    B, kappa = phase2_weights(state, normalize, live=~dead)
    return A, eff_std1, B, kappa, state.plan.membership.T


def _flat_pack(leaves, rows: int) -> jnp.ndarray:
    """K-stacked leaves -> one f32 ``(rows, d)`` matrix (leaf order)."""
    return jnp.concatenate(
        [x.reshape(rows, -1).astype(jnp.float32) for x in leaves], axis=1)


def _flat_unpack(new_flat: jnp.ndarray, cons_flat: jnp.ndarray,
                 leaves, treedef, rows: int):
    """Inverse of :func:`_flat_pack` for the round's two outputs: slice
    the ``(rows, d)`` / ``(d,)`` results back into per-leaf shapes and
    dtypes.  Shared by the in-core fast path and the client-sharded sync
    (`repro.sim.sharded`) so the leaf layout can never drift apart."""
    new_leaves, cons_leaves, off = [], [], 0
    for x in leaves:
        n = int(np.prod(x.shape[1:]))
        new_leaves.append(
            new_flat[:, off:off + n].reshape((rows,) + x.shape[1:])
            .astype(x.dtype))
        cons_leaves.append(
            cons_flat[off:off + n].reshape(x.shape[1:]).astype(x.dtype))
        off += n
    return (jax.tree.unflatten(treedef, new_leaves),
            jax.tree.unflatten(treedef, cons_leaves))


def _aggregate_flat(stacked_params, state: CWFLState, key: jax.Array,
                    normalize: bool, precode: bool,
                    mask: Optional[jnp.ndarray] = None,
                    alive: Optional[jnp.ndarray] = None,
                    guard: bool = False):
    """Flatten-once fast path: one (K, d) matrix through the fused
    single-pass round kernel instead of the per-leaf ``_mix_rows`` loop.
    The noise stream replicates the per-leaf path exactly (same key
    splits, same draw shapes — :func:`_flat_leaf_noise`), so for f32
    trees this is bit-compatible with the reference path."""
    leaves, treedef = jax.tree.flatten(stacked_params)
    K = leaves[0].shape[0]
    C = state.num_clusters
    k1, k2 = jax.random.split(key)
    A, eff_std1, B, kappa, m_back = round_coefficients(
        state, stacked_params, normalize, precode, mask, alive=alive)

    flat = _flat_pack(leaves, K)
    n1 = _flat_leaf_noise(k1, leaves, C, eff_std1)
    n2 = _flat_leaf_noise(k2, leaves, C, kappa)

    new_flat, cons_flat = cwfl_round_auto(flat, A, n1, B, n2, m_back,
                                          guard=guard)
    return _flat_unpack(new_flat, cons_flat, leaves, treedef, K)


def aggregate(stacked_params, state: CWFLState, key: jax.Array,
              normalize: bool = True, precode: bool = True,
              flat: Optional[bool] = None,
              mask: Optional[jnp.ndarray] = None,
              alive: Optional[jnp.ndarray] = None,
              guard: bool = False):
    """One CWFL sync round. Returns (new_stacked_params, consensus_mean).

    ``stacked_params``: pytree, every leaf (K, ...).
    ``normalize``: convex-combination mode (behaviorally faithful); False gives
      the literal eq. (8)/(9) weights (for equation-level unit tests).
    ``precode``: apply eq. (5) norm-limiting precoding (and its exact inverse
      scaling at the receiver, the COTAF-style de-precoding). With
      normalization these cancel in expectation; retained for faithfulness of
      the transmitted power constraint.
    ``flat``: route the whole round through the flatten-once fast path (the
      fused :mod:`repro.kernels.cwfl_round` kernel above ``PALLAS_MIN_DIM``).
      Default ``None`` auto-engages when every leaf is f32, where the fast
      path is bit-compatible with the per-leaf reference path (noise keys
      are replicated per leaf; the per-leaf dtype casts the reference path
      performs between phases are all no-ops).  Non-f32 trees default to
      the per-leaf path, whose between-phase rounding they depend on;
      ``flat=True`` forces the fast path (f32 accumulation end-to-end).
    ``mask``: optional (K,) {0,1} per-round participation folded into the
      round coefficients (mask-aware renormalization, see
      :func:`round_coefficients`).  The transmit side only — deciding
      whether absent clients still *receive* the phase-3 broadcast is the
      scenario layer's job (`repro.sim.engine` keeps their local params).
    ``alive``: optional (K,) {0,1} node-up vector of a fault scenario —
      crashed heads lose forced presence, all-crashed clusters become
      zeroed dead rows (see :func:`round_coefficients`).
    ``guard`` (STATIC flag): engage the kernel-level NaN/dead-row guard
      — non-finite signals are sanitized to 0 before the OTA matmuls so a
      poisoned transmit cannot NaN the consensus (the `repro.kernels`
      route mirrors it in the fused kernel).  Off by default: guard-off
      traces byte-identical jaxprs.
    """
    if flat is None:
        flat = all(x.dtype == jnp.float32
                   for x in jax.tree.leaves(stacked_params))
    if flat:
        return _aggregate_flat(stacked_params, state, key, normalize,
                               precode, mask, alive=alive, guard=guard)

    k1, k2 = jax.random.split(key)
    A, eff_std1, B, kappa, m_back = round_coefficients(
        state, stacked_params, normalize, precode, mask, alive=alive)
    if guard:
        # Per-leaf route of the same kernel guard: sanitize non-finite
        # signals before they meet the matmuls (0 × NaN = NaN — masking
        # alone cannot contain a poisoned transmit).
        stacked_params = jax.tree.map(
            lambda x: jnp.where(jnp.isfinite(x), x,
                                jnp.zeros((), x.dtype)),
            stacked_params)

    # Phase 1: OTA superposition at each head + receiver AWGN (eq. 8).
    theta_tilde = _mix_rows(A, stacked_params, k1, eff_std1)

    # Phase 2: heads exchange θ̃ over C(C-1) channel uses; receiver c mixes
    # with SNR weights W(c, j) plus its own θ̃_c (eq. 9, lemma 2).
    theta_bar = _mix_rows(B, theta_tilde, k2, kappa)

    # Phase 3: error-free downlink broadcast θ_k ← θ̄_{c(k)}.
    new_params = _mix_rows(m_back, theta_bar, None, None)

    consensus = jax.tree.map(lambda x: jnp.mean(x, axis=0), theta_bar)
    return new_params, consensus


def channel_uses_per_round(num_clients: int, num_clusters: int) -> dict:
    """Paper's efficiency claim: CWFL needs C(C−1) consensus channel uses +
    1 OTA slot per cluster, vs K(K−1) for fully-decentralized FL.

    Thin forward to `repro.obs.ledger.per_round_table` — the counts live
    on each registered strategy's ``Strategy.channel_uses`` so the
    in-scan telemetry ledger, the benchmark tables, and this legacy entry
    point can never disagree.  (Lazy import: core must not pay for — or
    cycle into — the strategies/obs layers unless asked.)"""
    from repro.obs.ledger import per_round_table
    return per_round_table(num_clients, num_clusters)
