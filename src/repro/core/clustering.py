"""SNR-aware, data-agnostic client clustering (paper §IV).

Each client runs K-means *offline* on an SNR feature space derived from the
topology G(V, L) and the inter-client channels h_{k,j}.  The feature vector of
client k is its link-SNR profile (row k of the K×K link-SNR matrix, in dB,
with outage links floored): geometrically-close clients share similar SNR
profiles and land in the same cluster, which is exactly the paper's
"clusters with high-SNR links" property.  The client nearest each centroid is
designated cluster-head.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ClusterPlan:
    """Output of the offline clustering phase.

    Registered as a jax pytree (all fields are arrays) so plans can ride a
    ``lax.scan`` carry / ``lax.cond`` branch — the scenario engine
    (`repro.sim`) re-clusters periodically inside the scanned round loop.
    """

    assignment: jnp.ndarray        # (K,) int cluster id per client
    heads: jnp.ndarray             # (C,) int client index of each cluster-head
    membership: jnp.ndarray        # (C, K) float {0,1}; membership[c, k]
    cluster_snr: jnp.ndarray       # (C,) ξ_c: mean member→head link SNR (linear)
    head_mask: jnp.ndarray         # (K,) {0,1} is-a-head indicator

    @property
    def num_clusters(self) -> int:
        return int(self.heads.shape[0])


jax.tree_util.register_pytree_node(
    ClusterPlan,
    lambda p: ((p.assignment, p.heads, p.membership, p.cluster_snr,
                p.head_mask), None),
    lambda _, c: ClusterPlan(*c))


def _kmeans(features: jnp.ndarray, num_clusters: int, key: jax.Array,
            iters: int = 50) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Plain Lloyd K-means with farthest-point ('kmeans++-lite') init."""
    K, _ = features.shape
    C = num_clusters

    # Farthest-point initialization (deterministic given the first pick).
    first = jax.random.randint(key, (), 0, K)

    def init_body(c, centers_idx):
        d2 = jnp.min(
            jnp.sum((features[:, None, :] - features[centers_idx][None], ) [0] ** 2,
                    axis=-1)
            + jnp.where(jnp.arange(C)[None, :] >= c, jnp.inf, 0.0),
            axis=1,
        )
        nxt = jnp.argmax(d2)
        return centers_idx.at[c].set(nxt)

    centers_idx = jnp.zeros((C,), jnp.int32).at[0].set(first)
    centers_idx = jax.lax.fori_loop(1, C, init_body, centers_idx)
    centroids = features[centers_idx]

    def lloyd(_, centroids):
        d2 = jnp.sum((features[:, None, :] - centroids[None]) ** 2, axis=-1)
        assign = jnp.argmin(d2, axis=1)
        onehot = jax.nn.one_hot(assign, C, dtype=features.dtype)   # (K, C)
        counts = jnp.maximum(onehot.sum(0), 1.0)                   # (C,)
        new = (onehot.T @ features) / counts[:, None]
        # Keep empty clusters where they were.
        empty = (onehot.sum(0) == 0)[:, None]
        return jnp.where(empty, centroids, new)

    centroids = jax.lax.fori_loop(0, iters, lloyd, centroids)
    d2 = jnp.sum((features[:, None, :] - centroids[None]) ** 2, axis=-1)
    return jnp.argmin(d2, axis=1), centroids


def snr_features(link_snr: jnp.ndarray, adjacency: jnp.ndarray,
                 floor_db: float = -30.0) -> jnp.ndarray:
    """Per-client SNR profile features (dB, outage links floored)."""
    snr_db = 10.0 * jnp.log10(jnp.maximum(link_snr, 1e-12))
    snr_db = jnp.where(adjacency, snr_db, floor_db)
    return jnp.maximum(snr_db, floor_db)


def make_cluster_plan(link_snr: jnp.ndarray, adjacency: jnp.ndarray,
                      num_clusters: int, key: jax.Array,
                      kmeans_iters: int = 50) -> ClusterPlan:
    """Full offline clustering: K-means on SNR features → heads → ξ_c."""
    K = link_snr.shape[0]
    feats = snr_features(link_snr, adjacency)
    assign, centroids = _kmeans(feats, num_clusters, key, kmeans_iters)

    # Head of cluster c = member closest to centroid c (paper §IV).
    d2 = jnp.sum((feats[:, None, :] - centroids[None]) ** 2, axis=-1)  # (K, C)
    d2_masked = jnp.where(assign[:, None] == jnp.arange(num_clusters)[None],
                          d2, jnp.inf)
    heads = jnp.argmin(d2_masked, axis=0)                              # (C,)

    membership = (assign[None, :] == jnp.arange(num_clusters)[:, None])
    membership = membership.astype(jnp.float32)                        # (C, K)

    # ξ_c: average member→head link SNR (excluding the head's zero self-link).
    snr_to_head = link_snr[heads]                                      # (C, K)
    head_onehot = jax.nn.one_hot(heads, K, dtype=jnp.float32)          # (C, K)
    member_not_head = membership * (1.0 - head_onehot)
    denom = jnp.maximum(member_not_head.sum(1), 1.0)
    cluster_snr = (snr_to_head * member_not_head).sum(1) / denom
    # Singleton clusters (head only): treat as max-SNR (noiseless local agg).
    cluster_snr = jnp.where(member_not_head.sum(1) > 0, cluster_snr,
                            jnp.max(link_snr))

    head_mask = head_onehot.sum(0)
    return ClusterPlan(assignment=assign, heads=heads, membership=membership,
                       cluster_snr=cluster_snr, head_mask=head_mask)


def reelect_heads(plan: ClusterPlan, link_snr: jnp.ndarray,
                  alive: jnp.ndarray) -> ClusterPlan:
    """Head-failure handoff (DESIGN.md §Faults): re-elect crashed heads.

    Pure jnp and `lax.scan`/`vmap`-legal — the engine calls it every
    fault round; the decision logic is all ``where``s:

    * a cluster whose head is still up keeps it (election stability —
      handoffs happen on failure, not on every SNR wobble);
    * a dead head is replaced by the *surviving max-gain member*: the
      live member of the same cluster with the largest within-cluster
      aggregate link SNR Σ_j membership[c,j]·ξ_{k,j} (the connectivity a
      phase-1 receiver actually uses);
    * a fully-dead cluster keeps its (dead) head — downstream the
      alive-aware round coefficients zero its row entirely
      (`cwfl.round_coefficients`), so the stale index is inert.

    Membership/assignment are untouched (failure is not churn; periodic
    re-clustering still owns geometry changes) while ``cluster_snr`` is
    re-derived for the new heads with `make_cluster_plan`'s own ξ_c rule,
    so the phase-2 consensus weights re-derive from the survivor's links.
    """
    K = link_snr.shape[0]
    a = alive.astype(jnp.float32)
    # score[c, k]: client k's aggregate link SNR into cluster c's members.
    score = plan.membership @ link_snr.T                           # (C, K)
    cand = plan.membership * a[None, :]                            # (C, K)
    elig = jnp.where(cand > 0, score, -jnp.inf)
    new_heads = jnp.argmax(elig, axis=1).astype(plan.heads.dtype)  # (C,)
    any_cand = jnp.any(cand > 0, axis=1)
    keep = a[plan.heads] > 0
    heads = jnp.where(keep, plan.heads,
                      jnp.where(any_cand, new_heads, plan.heads))

    head_onehot = jax.nn.one_hot(heads, K, dtype=jnp.float32)      # (C, K)
    head_mask = head_onehot.sum(0)

    # ξ_c for the (possibly new) heads — same rule as make_cluster_plan.
    snr_to_head = link_snr[heads]                                  # (C, K)
    member_not_head = plan.membership * (1.0 - head_onehot)
    denom = jnp.maximum(member_not_head.sum(1), 1.0)
    cluster_snr = (snr_to_head * member_not_head).sum(1) / denom
    cluster_snr = jnp.where(member_not_head.sum(1) > 0, cluster_snr,
                            jnp.max(link_snr))
    return ClusterPlan(assignment=plan.assignment, heads=heads,
                       membership=plan.membership, cluster_snr=cluster_snr,
                       head_mask=head_mask)


def consensus_weights(cluster_snr: jnp.ndarray) -> jnp.ndarray:
    """Paper eq. (9) weights: W(c, j) = ξ_j / Σ_{j'≠c} ξ_{j'},  W(c, c) = 0.

    Rows index the receiving head c, columns the transmitting head j.
    Each row sums to 1 over j≠c.
    """
    C = cluster_snr.shape[0]
    xi = jnp.asarray(cluster_snr, jnp.float32)
    off = 1.0 - jnp.eye(C)
    denom = (off * xi[None, :]).sum(axis=1, keepdims=True)
    return off * xi[None, :] / jnp.maximum(denom, 1e-12)
