"""Wireless uplink channel model: power control, precoding, OTA MAC (paper §III).

Implements, in order of the paper's equations:

* eq. (4)  y = Σ_k h_k x_k + w,  E‖x_k‖² ≤ P_k        (noisy superposition MAC)
* eq. (5)  x_k = sqrt(P_k^t) θ_k,  P_k^t = min(P_k, P_k / E‖θ_k‖²)
* water-filling power allocation over the per-link effective channel |h_{k,s}|
* eq. (8)  θ̃_c = P^{-1/2} y_c = Σ_k p_k θ_k + w̃_c,  p_k = sqrt(P_k/P)

Everything is pure-JAX and shape-polymorphic so it can be vmapped over
clusters / rounds and reused verbatim inside the shard_map collective
(`repro.dist.ota_collectives`).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def water_filling(channel_gains: jnp.ndarray, total_power: float,
                  iters: int = 60) -> jnp.ndarray:
    """Water-filling power allocation (paper §III, [22]).

    Maximizes Σ_k log(1 + P_k g_k) s.t. Σ_k P_k = P, P_k ≥ 0, where
    ``g_k = |h_k|^2 / σ²`` is the normalized channel gain of client k's link
    to its receiver. Solved by bisection on the water level µ:
        P_k = max(µ − 1/g_k, 0).

    Args:
      channel_gains: (K,) positive effective gains g_k.
      total_power: scalar P.
    Returns:
      (K,) powers summing to ``total_power``.
    """
    g = jnp.maximum(jnp.asarray(channel_gains, jnp.float32), 1e-12)
    inv_g = 1.0 / g
    lo = jnp.zeros(())
    hi = total_power + jnp.max(inv_g)

    def body(_, carry):
        lo, hi = carry
        mu = 0.5 * (lo + hi)
        p = jnp.maximum(mu - inv_g, 0.0)
        too_much = jnp.sum(p) > total_power
        return jnp.where(too_much, lo, mu), jnp.where(too_much, mu, hi)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    mu = 0.5 * (lo + hi)
    p = jnp.maximum(mu - inv_g, 0.0)
    # Exact renormalization onto the simplex Σ P_k = P (bisection residual).
    s = jnp.sum(p)
    return jnp.where(s > 0, p * (total_power / jnp.maximum(s, 1e-12)),
                     jnp.full_like(p, total_power / p.shape[0]))


def precoding_factor(p_k: jnp.ndarray, theta_sq_norm: jnp.ndarray) -> jnp.ndarray:
    """Eq. (5): P_k^t = min(P_k, P_k / E‖θ_k^t‖²).

    The expectation is estimated by the instantaneous squared norm (the
    standard COTAF-style estimator; clients know their own parameters).
    Guarantees E‖x_k‖² = P_k^t ‖θ‖² ≤ P_k.
    """
    return jnp.minimum(p_k, p_k / jnp.maximum(theta_sq_norm, 1.0))


def precode_amplitude(p_k: jnp.ndarray, mean_sq_norm: jnp.ndarray) -> jnp.ndarray:
    """Eq. (5) amplitude scale ``sqrt(P_k^t / P_k) ≤ 1``.

    ``mean_sq_norm`` is the per-CHANNEL-USE signal power E‖θ_k‖²/d (one
    parameter per channel use) — the estimator of eq. (5)'s E‖θ_k^t‖²
    shared by CWFL and COTAF (see DESIGN.md §1 for why the total d-dim
    norm is the wrong estimator).
    """
    return jnp.sqrt(precoding_factor(p_k, mean_sq_norm)
                    / jnp.maximum(p_k, 1e-12))


def ota_mac(signals: jnp.ndarray, amplitudes: jnp.ndarray, mask: jnp.ndarray,
            key: jax.Array, noise_std: float | jnp.ndarray) -> jnp.ndarray:
    """Noisy superposition MAC (eq. 4 after channel inversion).

    y = Σ_k mask_k · a_k · s_k + w,  w ~ N(0, noise_std² I_d)

    Args:
      signals: (K, d) channel-inverted transmit signals (θ_k rows).
      amplitudes: (K,) per-client amplitude scaling sqrt(P_k^t).
      mask: (K,) {0,1} membership of this receiver's MAC.
      key: PRNG key for the receiver noise.
      noise_std: receiver noise standard deviation σ.
    Returns:
      (d,) received signal.
    """
    y = jnp.einsum("k,kd->d", amplitudes * mask, signals)
    w = noise_std * jax.random.normal(key, y.shape, dtype=y.dtype)
    return y + w


def snr_db_to_noise_var(total_power: float, snr_db: float) -> float:
    """σ² such that overall SNR ξ = P/σ² equals ``snr_db`` (paper: ξ = 40 dB)."""
    return total_power / (10.0 ** (snr_db / 10.0))
