"""Baseline aggregation strategies the paper compares against (§II, §V).

* ``fedavg``        — ideal noiseless server aggregation (eq. 2), upper bound.
* ``cotaf``         — the paper's *modified* COTAF [5]: all K clients transmit
                      raw (not differenced) parameters OTA to one server with
                      water-filling power allocation; single noisy MAC.
* ``decentralized`` — fully-decentralized consensus (eq. 3) over G(V, L) with
                      Metropolis–Hastings doubly-stochastic mixing; K(K−1)
                      channel uses per round, per-link receiver noise.
* FedProx           — a *local-objective* modification (proximal term), see
                      ``repro.training.local.fedprox_grad`` — composes with
                      any of the aggregation strategies above (the paper
                      reports COTAF-Prox and CWFL-Prox).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import channel as ch
from repro.core.cwfl import _mix_rows, per_client_mean_sq
from repro.core.topology import Topology


# ---------------------------------------------------------------------------
# FedAvg (ideal, noiseless).
# ---------------------------------------------------------------------------

def fedavg_aggregate(stacked_params, weights: Optional[jnp.ndarray] = None):
    """θ ← Σ_k p_k θ_k with Σ p_k = 1 (eq. 2); returns (stacked, consensus)."""
    K = jax.tree.leaves(stacked_params)[0].shape[0]
    if weights is None:
        weights = jnp.full((K,), 1.0 / K, jnp.float32)
    weights = weights / weights.sum()
    consensus = _mix_rows(weights[None, :], stacked_params, None, None)
    consensus = jax.tree.map(lambda x: x[0], consensus)
    new = jax.tree.map(
        lambda c: jnp.broadcast_to(c[None], (K,) + c.shape), consensus)
    return new, consensus


# ---------------------------------------------------------------------------
# COTAF-modified: single-server OTA MAC.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class COTAFState:
    client_power: jnp.ndarray     # (K,) water-filled P_k
    total_power: float
    noise_std: jnp.ndarray        # scalar σ at the server


def cotaf_setup(topology: Topology, key: jax.Array,
                snr_db: Optional[float] = None,
                server: Optional[int] = None) -> COTAFState:
    """Water-fill power over client→server links. The 'server' is the client
    with the best average channel (a base station would sit centrally)."""
    del key
    noise_var = topology.noise_var
    if snr_db is not None:
        noise_var = ch.snr_db_to_noise_var(topology.total_power, snr_db)
    mean_gain = (jnp.abs(topology.link_gain) ** 2).mean(axis=1)
    s = int(jnp.argmax(mean_gain)) if server is None else server
    g = jnp.abs(topology.link_gain[:, s]) ** 2 / noise_var
    g = g.at[s].set(jnp.max(g))  # the server's own data arrives locally
    power = ch.water_filling(g, topology.total_power)
    return COTAFState(client_power=power,
                      total_power=float(topology.total_power),
                      noise_std=jnp.asarray(jnp.sqrt(noise_var), jnp.float32))


def cotaf_aggregate(stacked_params, state: COTAFState, key: jax.Array,
                    normalize: bool = True, precode: bool = True):
    """θ̃ = Σ_k sqrt(P_k/P) θ_k + w̃ over ONE shared MAC (all K at once)."""
    K = jax.tree.leaves(stacked_params)[0].shape[0]
    p = jnp.sqrt(state.client_power / state.total_power)          # (K,)
    if precode:
        # eq. (5) on the per-channel-use mean square (DESIGN.md §1) — same
        # estimator + amplitude as CWFL's precode_scale, without heads.
        p = p * ch.precode_amplitude(state.client_power,
                                     per_client_mean_sq(stacked_params))
    A = p[None, :]                                                # (1, K)
    eff_std = (state.noise_std / jnp.sqrt(state.total_power))[None]
    if normalize:
        rows = jnp.maximum(A.sum(axis=1, keepdims=True), 1e-12)
        agg = _mix_rows(A / rows, stacked_params, key, eff_std / rows[:, 0])
    else:
        agg = _mix_rows(A, stacked_params, key, eff_std)
    consensus = jax.tree.map(lambda x: x[0], agg)
    new = jax.tree.map(
        lambda c: jnp.broadcast_to(c[None], (K,) + c.shape), consensus)
    return new, consensus


# ---------------------------------------------------------------------------
# Fully-decentralized consensus (eq. 3).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DecentralizedState:
    mixing: jnp.ndarray          # (K, K) symmetric doubly-stochastic W̃
    noise_std: jnp.ndarray       # scalar per-link receiver noise σ
    total_power: float


def metropolis_weights(adjacency: jnp.ndarray) -> jnp.ndarray:
    """Symmetric doubly-stochastic mixing from a graph (Metropolis–Hastings):
    W(i,j) = 1/(1+max(d_i, d_j)) for edges, diagonal = 1 − Σ_j W(i,j)."""
    adj = adjacency.astype(jnp.float32) * (1.0 - jnp.eye(adjacency.shape[0]))
    deg = adj.sum(axis=1)
    denom = 1.0 + jnp.maximum(deg[:, None], deg[None, :])
    W = adj / denom
    return W + jnp.diag(1.0 - W.sum(axis=1))


def decentralized_setup(topology: Topology, key: jax.Array,
                        snr_db: Optional[float] = None) -> DecentralizedState:
    del key
    noise_var = topology.noise_var
    if snr_db is not None:
        noise_var = ch.snr_db_to_noise_var(topology.total_power, snr_db)
    return DecentralizedState(
        mixing=metropolis_weights(topology.adjacency),
        noise_std=jnp.asarray(jnp.sqrt(noise_var), jnp.float32),
        total_power=float(topology.total_power))


def decentralized_aggregate(stacked_params, state: DecentralizedState,
                            key: jax.Array):
    """θ_k ← Σ_j W̃(k,j) θ_j + per-neighbour receive noise (K(K−1) uses).

    Effective noise at node k: Σ_{j≠k} W̃(k,j) ṽ_j with ṽ ~ N(0, σ²/P) —
    std_k = sqrt(Σ_j W̃(k,j)²) σ/√P (same equivalent model as lemma 2).
    """
    W = state.mixing
    off = W * (1.0 - jnp.eye(W.shape[0]))
    eff_std = jnp.sqrt(jnp.sum(off**2, axis=1)) * (
        state.noise_std / jnp.sqrt(state.total_power))
    mixed = _mix_rows(W, stacked_params, key, eff_std)
    consensus = jax.tree.map(lambda x: jnp.mean(x, axis=0), mixed)
    return mixed, consensus
