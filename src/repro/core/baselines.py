"""Baseline aggregation strategies the paper compares against (§II, §V).

* ``fedavg``        — ideal noiseless server aggregation (eq. 2), upper bound.
* ``cotaf``         — the paper's *modified* COTAF [5]: all K clients transmit
                      raw (not differenced) parameters OTA to one server with
                      water-filling power allocation; single noisy MAC.
* ``decentralized`` — fully-decentralized consensus (eq. 3) over G(V, L) with
                      Metropolis–Hastings doubly-stochastic mixing; K(K−1)
                      channel uses per round, per-link receiver noise.
* FedProx           — a *local-objective* modification (proximal term), see
                      ``repro.training.local.fedprox_wrap`` — composes with
                      any of the aggregation strategies above; the paper's
                      COTAF-Prox and CWFL-Prox are registered as the
                      first-class ``cotaf_prox`` / ``cwfl_prox`` strategies
                      in `repro.strategies`.

These are plain operators on stacked pytrees; their engine-facing
packaging (setup/rebuild/receive rules, capability flags) lives in
`repro.strategies.builtin`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import channel as ch
from repro.core.cwfl import _mix_rows, per_client_mean_sq
from repro.core.topology import Topology


# ---------------------------------------------------------------------------
# FedAvg (ideal, noiseless).
# ---------------------------------------------------------------------------

def fedavg_aggregate(stacked_params, weights: Optional[jnp.ndarray] = None):
    """θ ← Σ_k p_k θ_k with Σ p_k = 1 (eq. 2); returns (stacked, consensus)."""
    K = jax.tree.leaves(stacked_params)[0].shape[0]
    if weights is None:
        weights = jnp.full((K,), 1.0 / K, jnp.float32)
    weights = weights / weights.sum()
    consensus = _mix_rows(weights[None, :], stacked_params, None, None)
    consensus = jax.tree.map(lambda x: x[0], consensus)
    new = jax.tree.map(
        lambda c: jnp.broadcast_to(c[None], (K,) + c.shape), consensus)
    return new, consensus


# ---------------------------------------------------------------------------
# COTAF-modified: single-server OTA MAC.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class COTAFState:
    client_power: jnp.ndarray     # (K,) water-filled P_k
    total_power: float
    noise_std: jnp.ndarray        # scalar σ at the server
    server: Optional[jnp.ndarray] = None   # receiver index (None = unknown)


jax.tree_util.register_pytree_node(
    COTAFState,
    lambda s: ((s.client_power, s.noise_std, s.server), s.total_power),
    lambda aux, c: COTAFState(client_power=c[0], total_power=aux,
                              noise_std=c[1], server=c[2]))


def cotaf_participation(state: COTAFState,
                        mask: Optional[jnp.ndarray]) -> Optional[jnp.ndarray]:
    """(K,) effective participation for one COTAF round, or ``None``.

    The server is forced present — it is the MAC *receiver* and its own
    data arrives locally without crossing the channel, so masking it out
    would discard the aggregate at the one node that holds it (the same
    receiver rule as ``cwfl.participation_weights`` for cluster heads).
    States built before server tracking (``server=None``) fall back to
    the raw mask.
    """
    if mask is None:
        return None
    m = mask.astype(jnp.float32)
    if state.server is None:
        return m
    K = m.shape[0]
    return jnp.where(jnp.arange(K) == state.server, 1.0, m)


def cotaf_state_from_gains(link_gain: jnp.ndarray, total_power: float,
                           noise_var, server=None,
                           csi_perturb: Optional[jnp.ndarray] = None,
                           alive: Optional[jnp.ndarray] = None
                           ) -> COTAFState:
    """COTAF state from a raw (K, K) complex gain matrix — the traced half
    of :func:`cotaf_setup`, usable inside ``lax.scan``/``vmap`` (the
    scenario engine rebuilds it per round from a time-varying channel).

    Server-selection rule: the server is the client with the largest
    mean received link gain ``mean_j |h_{k,j}|²`` — the node a base
    station would approximate, sitting where aggregate connectivity is
    best.  Selection is ``jnp.argmax`` (a traced op, no host sync); pass
    ``server`` (int or traced scalar) to pin it explicitly.

    ``csi_perturb``: optional (K,) multiplicative factor on the
    water-filling gains (imperfect CSI at the allocator — same semantics
    as ``cwfl.state_from_plan``).

    ``alive``: optional (K,) {0,1} node-up vector (fault scenarios,
    DESIGN.md §Faults) — the server FAILOVER rule: selection argmaxes
    over *surviving* nodes only, so a crashed server hands the role to
    the best-connected live node that round.  With every node down the
    unmasked argmax stands (the engine's all-masked guard skips the sync
    anyway).  ``alive=None`` is byte-identical to the faultless path.
    """
    if server is None:
        mean_gain = (jnp.abs(link_gain) ** 2).mean(axis=1)
        if alive is None:
            server = jnp.argmax(mean_gain)
        else:
            up = alive > 0
            masked = jnp.where(up, mean_gain, -jnp.inf)
            server = jnp.where(jnp.any(up), jnp.argmax(masked),
                               jnp.argmax(mean_gain))
    s = jnp.asarray(server)
    g = jnp.abs(link_gain[:, s]) ** 2 / noise_var
    g = g.at[s].set(jnp.max(g))  # the server's own data arrives locally
    if csi_perturb is not None:
        g = g * csi_perturb
    power = ch.water_filling(g, total_power)
    return COTAFState(client_power=power,
                      total_power=total_power,
                      noise_std=jnp.sqrt(noise_var).astype(jnp.float32),
                      server=s)


def cotaf_setup(topology: Topology, key: jax.Array,
                snr_db: Optional[float] = None,
                server: Optional[int] = None) -> COTAFState:
    """Water-fill power over client→server links.

    The 'server' is the client with the best *average* channel (the rule
    a central base station approximates); see
    :func:`cotaf_state_from_gains` for the precise selection rule.  The
    whole setup is traced jnp — no host-side ``int()`` sync — so it can
    live inside a scanned round loop or under ``vmap`` over scenario
    scalars (``snr_db`` may be a tracer).
    """
    del key
    noise_var = topology.noise_var
    if snr_db is not None:
        noise_var = ch.snr_db_to_noise_var(topology.total_power, snr_db)
    return cotaf_state_from_gains(topology.link_gain,
                                  float(topology.total_power), noise_var,
                                  server=server)


def cotaf_aggregate(stacked_params, state: COTAFState, key: jax.Array,
                    normalize: bool = True, precode: bool = True,
                    mask: Optional[jnp.ndarray] = None):
    """θ̃ = Σ_k sqrt(P_k/P) θ_k + w̃ over ONE shared MAC (all K at once).

    ``mask``: optional (K,) {0,1} per-round participation — absent clients
    get a zero MAC amplitude before the renormalization (mask-aware, same
    semantics as ``cwfl.round_coefficients``; the server is forced
    present, :func:`cotaf_participation`); an all-ones mask is
    bit-identical to ``mask=None``.
    """
    K = jax.tree.leaves(stacked_params)[0].shape[0]
    p = jnp.sqrt(state.client_power / state.total_power)          # (K,)
    part = cotaf_participation(state, mask)
    if part is not None:
        p = p * part.astype(p.dtype)
    if precode:
        # eq. (5) on the per-channel-use mean square (DESIGN.md §1) — same
        # estimator + amplitude as CWFL's precode_scale, without heads.
        p = p * ch.precode_amplitude(state.client_power,
                                     per_client_mean_sq(stacked_params))
    A = p[None, :]                                                # (1, K)
    eff_std = (state.noise_std / jnp.sqrt(state.total_power))[None]
    if normalize:
        rows = jnp.maximum(A.sum(axis=1, keepdims=True), 1e-12)
        agg = _mix_rows(A / rows, stacked_params, key, eff_std / rows[:, 0])
    else:
        agg = _mix_rows(A, stacked_params, key, eff_std)
    consensus = jax.tree.map(lambda x: x[0], agg)
    new = jax.tree.map(
        lambda c: jnp.broadcast_to(c[None], (K,) + c.shape), consensus)
    return new, consensus


# ---------------------------------------------------------------------------
# Fully-decentralized consensus (eq. 3).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DecentralizedState:
    mixing: jnp.ndarray          # (K, K) symmetric doubly-stochastic W̃
    noise_std: jnp.ndarray       # scalar per-link receiver noise σ
    total_power: float


jax.tree_util.register_pytree_node(
    DecentralizedState,
    lambda s: ((s.mixing, s.noise_std), s.total_power),
    lambda aux, c: DecentralizedState(mixing=c[0], noise_std=c[1],
                                      total_power=aux))


def metropolis_weights(adjacency: jnp.ndarray) -> jnp.ndarray:
    """Symmetric doubly-stochastic mixing from a graph (Metropolis–Hastings):
    W(i,j) = 1/(1+max(d_i, d_j)) for edges, diagonal = 1 − Σ_j W(i,j)."""
    adj = adjacency.astype(jnp.float32) * (1.0 - jnp.eye(adjacency.shape[0]))
    deg = adj.sum(axis=1)
    denom = 1.0 + jnp.maximum(deg[:, None], deg[None, :])
    W = adj / denom
    return W + jnp.diag(1.0 - W.sum(axis=1))


def decentralized_state_from_graph(adjacency: jnp.ndarray,
                                   total_power: float,
                                   noise_var) -> DecentralizedState:
    """Decentralized state from a raw adjacency — traced-friendly half of
    :func:`decentralized_setup` for per-round rebuilds in the scenario
    engine.  Isolated nodes (degree 0 — e.g. clients masked out of a
    round) get ``W(k,k) = 1`` and zero effective noise, i.e. they keep
    their parameters unchanged — exactly the no-participation semantics.
    """
    return DecentralizedState(
        mixing=metropolis_weights(adjacency),
        noise_std=jnp.sqrt(noise_var).astype(jnp.float32),
        total_power=total_power)


def decentralized_setup(topology: Topology, key: jax.Array,
                        snr_db: Optional[float] = None) -> DecentralizedState:
    del key
    noise_var = topology.noise_var
    if snr_db is not None:
        noise_var = ch.snr_db_to_noise_var(topology.total_power, snr_db)
    return decentralized_state_from_graph(
        topology.adjacency, float(topology.total_power), noise_var)


def decentralized_aggregate(stacked_params, state: DecentralizedState,
                            key: jax.Array):
    """θ_k ← Σ_j W̃(k,j) θ_j + per-neighbour receive noise (K(K−1) uses).

    Effective noise at node k: Σ_{j≠k} W̃(k,j) ṽ_j with ṽ ~ N(0, σ²/P) —
    std_k = sqrt(Σ_j W̃(k,j)²) σ/√P (same equivalent model as lemma 2).
    """
    W = state.mixing
    off = W * (1.0 - jnp.eye(W.shape[0]))
    eff_std = jnp.sqrt(jnp.sum(off**2, axis=1)) * (
        state.noise_std / jnp.sqrt(state.total_power))
    mixed = _mix_rows(W, stacked_params, key, eff_std)
    consensus = jax.tree.map(lambda x: jnp.mean(x, axis=0), mixed)
    return mixed, consensus
