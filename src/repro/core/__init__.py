"""CWFL core: the paper's contribution (channel, clustering, aggregation)."""
from repro.core.topology import Topology, TopologyConfig, make_topology
from repro.core import channel
from repro.core import clustering
from repro.core import cwfl
from repro.core import baselines
