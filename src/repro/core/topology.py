"""Client geometry, pathloss and outage-derived graph topology (paper §III, §V).

The paper places K wireless devices in a plane; each link (k, j) is a
Rayleigh-faded channel with distance-dependent pathloss

    h_{k,j} = sqrt(P_k) * (d_0^{-1} d_{k,j})^{-ς/2} * h̃_{k,j},   h̃ ~ CN(0, 1)

(the paper writes the exponent as +ς/2 on (d0^{-1} d)^{ς/2} multiplying the
transmit amplitude; physically the received amplitude decays with distance, so
we use the decaying convention and note it).  Pilot signals determine which
links are in outage; surviving links define the undirected graph G(V, L).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TopologyConfig:
    num_clients: int = 50
    area_size: float = 100.0          # clients placed uniformly in [0, area]^2
    d0: float = 1.0                   # reference distance (m)
    pathloss_exp: float = 2.2         # ς
    noise_var: float = 1.0            # receiver AWGN variance sigma^2 (pre power-scale)
    total_power: float = 1e4          # P = sum_k P_k (40 dB overall SNR for sigma^2=1)
    outage_snr_db: float = -5.0       # links below this SNR are in outage
    num_hotspots: int = 3             # geometric hotspots -> natural SNR clusters
    hotspot_std: float = 6.0


@dataclasses.dataclass(frozen=True)
class Topology:
    """Static wireless topology: positions, complex link gains, SNRs, graph."""

    positions: jnp.ndarray            # (K, 2)
    link_gain: jnp.ndarray            # (K, K) complex gains h̃ * pathloss  (diag=0)
    link_snr: jnp.ndarray             # (K, K) |h|^2 * Pref / sigma^2  (diag=0)
    adjacency: jnp.ndarray            # (K, K) bool, outage-pruned graph L
    noise_var: float
    total_power: float

    @property
    def num_clients(self) -> int:
        return int(self.positions.shape[0])

    def snr_db(self) -> jnp.ndarray:
        return 10.0 * jnp.log10(jnp.maximum(self.link_snr, 1e-12))


def pathloss_amplitude(positions: jnp.ndarray,
                       cfg: TopologyConfig) -> jnp.ndarray:
    """(K, K) amplitude pathloss (d/d0)^{-ς/2} from positions — the single
    source of the distance convention (ε-regularized distance, clamp at
    d0), shared with the time-varying channel view in
    `repro.sim.processes` so per-round re-derivations can never drift
    from the seed topology's rules."""
    diff = positions[:, None, :] - positions[None, :, :]
    dist = jnp.sqrt(jnp.sum(diff**2, axis=-1) + 1e-9)
    dist = jnp.maximum(dist, cfg.d0)
    return (dist / cfg.d0) ** (-cfg.pathloss_exp / 2.0)


def link_stats(link_gain: jnp.ndarray, cfg: TopologyConfig):
    """(link_snr, adjacency) from a (K, K) complex gain matrix: SNR at the
    equal-split reference power P/K and the dB-threshold outage pruning —
    shared with `repro.sim.processes.channel_view` (same rationale as
    :func:`pathloss_amplitude`)."""
    K = link_gain.shape[0]
    p_ref = cfg.total_power / K
    link_snr = (jnp.abs(link_gain) ** 2) * p_ref / cfg.noise_var
    link_snr = link_snr * (1.0 - jnp.eye(K))
    snr_db = 10.0 * jnp.log10(jnp.maximum(link_snr, 1e-12))
    adjacency = (snr_db >= cfg.outage_snr_db) & ~jnp.eye(K, dtype=bool)
    return link_snr, adjacency


def make_topology(key: jax.Array, cfg: Optional[TopologyConfig] = None) -> Topology:
    """Draw a stationary topology (paper: channel constant across rounds)."""
    cfg = cfg or TopologyConfig()
    K = cfg.num_clients
    k_pos, k_hot, k_re, k_im = jax.random.split(key, 4)

    # Clients cluster geometrically around hotspots (models D2D neighbourhoods;
    # this is what makes SNR-based K-means produce meaningful clusters).
    hot = jax.random.uniform(k_hot, (cfg.num_hotspots, 2)) * cfg.area_size
    assign = jax.random.randint(k_pos, (K,), 0, cfg.num_hotspots)
    jitter = jax.random.normal(jax.random.fold_in(k_pos, 1), (K, 2)) * cfg.hotspot_std
    positions = hot[assign] + jitter

    # Pairwise distances and Rayleigh small-scale fading.
    pathloss_amp = pathloss_amplitude(positions, cfg)
    re = jax.random.normal(k_re, (K, K)) / jnp.sqrt(2.0)
    im = jax.random.normal(k_im, (K, K)) / jnp.sqrt(2.0)
    h_tilde = re + 1j * im
    # Symmetric channel (reciprocity): use upper triangle mirrored.
    iu = jnp.triu(jnp.ones((K, K), bool), k=1)
    h_tilde = jnp.where(iu, h_tilde, jnp.conj(h_tilde.T))
    link_gain = pathloss_amp * h_tilde
    link_gain = link_gain * (1.0 - jnp.eye(K))

    # Link SNR at reference (equal-split) power P/K + outage pruning.
    link_snr, adjacency = link_stats(link_gain, cfg)

    return Topology(
        positions=positions,
        link_gain=link_gain,
        link_snr=link_snr,
        adjacency=adjacency,
        noise_var=cfg.noise_var,
        total_power=cfg.total_power,
    )
