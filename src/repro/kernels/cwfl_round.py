"""Pallas TPU kernel: the fused single-pass CWFL sync round (Algorithm 1).

The unfused round executes eq. (8)/(9) + broadcast as three separate passes
over the ``d``-dimensional flattened parameter state:

    θ̃ = Ã·S + n₁          phase 1: intra-cluster OTA MAC      (C, d)
    θ̄ = B̃·θ̃ + n₂          phase 2: inter-head consensus mix   (C, d)
    new = Mᵀ·θ̄            phase 3: error-free broadcast        (K, d)
    consensus = mean_c θ̄                                        (d,)

which costs one HBM write + read of θ̃ and one write + two reads of θ̄ on
top of the unavoidable S read and new/consensus write.  This kernel runs
the whole round per ``d``-tile in VMEM: the tiny ``(C, K)``, ``(C, C)``
and ``(K, C)`` weight matrices stay fully VMEM-resident across the grid,
the ``(K, TILE)`` signal block is read once, and only the final
``new``/``consensus`` tiles are written back — the intermediate θ̃/θ̄
never touch HBM (see :func:`hbm_bytes_model` and DESIGN.md §Perf).

TPU-native notes (DESIGN.md §8): all three matmuls ride the MXU via
``dot_general`` with ``preferred_element_type=f32`` (bf16 signals
accumulate in f32); tiles are 128-lane aligned; ``d`` is padded to a tile
multiple internally and the pad sliced off (ragged last tile).  Validated
in interpret mode against :func:`repro.kernels.ref.cwfl_round_ref`.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ota_aggregate import DEFAULT_TILE, resolve_interpret

# Below this flat dimension the round is a handful of tiny matmuls; the
# jnp reference is a single fused XLA computation and the kernel's tile
# machinery cannot pay off.
PALLAS_MIN_DIM = 512


def _cwfl_round_kernel(a_ref, b_ref, m_ref, s_ref, n1_ref, n2_ref,
                       new_ref, cons_ref):
    """Grid: (d // TILE,). Blocks: a (C, K), b (C, C), m (K, C) —
    VMEM-resident for the whole grid; s (K, TILE), n1/n2 (C, TILE)
    streamed; new (K, TILE) and cons (1, TILE) written once."""
    s = s_ref[...].astype(jnp.float32)                       # (K, T)
    a = a_ref[...].astype(jnp.float32)                       # (C, K)
    b = b_ref[...].astype(jnp.float32)                       # (C, C)
    m = m_ref[...].astype(jnp.float32)                       # (K, C)

    dims = (((1,), (0,)), ((), ()))
    theta_tilde = jax.lax.dot_general(
        a, s, dims, preferred_element_type=jnp.float32)
    theta_tilde = theta_tilde + n1_ref[...].astype(jnp.float32)   # (C, T)
    theta_bar = jax.lax.dot_general(
        b, theta_tilde, dims, preferred_element_type=jnp.float32)
    theta_bar = theta_bar + n2_ref[...].astype(jnp.float32)       # (C, T)
    new = jax.lax.dot_general(
        m, theta_bar, dims, preferred_element_type=jnp.float32)   # (K, T)
    new_ref[...] = new.astype(new_ref.dtype)
    cons_ref[...] = jnp.mean(theta_bar, axis=0, keepdims=True)


def _fit_tile(tile: int, d: int) -> int:
    """Clamp the d-tile to the 128-lane-aligned cover of d (no point
    padding a 512-wide round out to a 2048 tile)."""
    return max(128, min(tile, -(-d // 128) * 128))


@functools.partial(jax.jit, static_argnames=("tile", "interpret", "guard"))
def cwfl_round(signals: jnp.ndarray, phase1: jnp.ndarray,
               noise1: jnp.ndarray, phase2: jnp.ndarray,
               noise2: jnp.ndarray, broadcast: jnp.ndarray, *,
               tile: int = DEFAULT_TILE,
               interpret: Optional[bool] = None, guard: bool = False):
    """One fused CWFL sync round over flat client signals.

    signals: (K, d) client parameter vectors (f32/bf16; f32 accumulate).
    phase1:  (C, K) OTA MAC amplitudes Ã (precoded/normalized by caller).
    noise1:  (C, d) phase-1 receiver AWGN (pre-generated).
    phase2:  (C, C) consensus mix B̃.
    noise2:  (C, d) phase-2 equivalent receiver noise.
    broadcast: (K, C) phase-3 downlink matrix (usually ``membership.T``).
    guard (static): in-kernel NaN/dead-Ã-row guard (fault scenarios).
    Returns ``(new (K, d) signals.dtype, consensus (d,) f32)``.
    """
    interpret = resolve_interpret(interpret)
    K, d = signals.shape
    C = phase1.shape[0]
    tile = _fit_tile(tile, d)
    dp = -(-d // tile) * tile
    if dp != d:
        signals = jnp.pad(signals, ((0, 0), (0, dp - d)))
        noise1 = jnp.pad(noise1, ((0, 0), (0, dp - d)))
        noise2 = jnp.pad(noise2, ((0, 0), (0, dp - d)))

    new, cons = pl.pallas_call(
        _cwfl_round_kernel_guard if guard else _cwfl_round_kernel,
        grid=(dp // tile,),
        in_specs=[
            pl.BlockSpec((C, K), lambda t: (0, 0)),
            pl.BlockSpec((C, C), lambda t: (0, 0)),
            pl.BlockSpec((K, C), lambda t: (0, 0)),
            pl.BlockSpec((K, tile), lambda t: (0, t)),
            pl.BlockSpec((C, tile), lambda t: (0, t)),
            pl.BlockSpec((C, tile), lambda t: (0, t)),
        ],
        out_specs=[
            pl.BlockSpec((K, tile), lambda t: (0, t)),
            pl.BlockSpec((1, tile), lambda t: (0, t)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((K, dp), signals.dtype),
            jax.ShapeDtypeStruct((1, dp), jnp.float32),
        ],
        interpret=interpret,
    )(phase1.astype(jnp.float32), phase2.astype(jnp.float32),
      broadcast.astype(jnp.float32), signals, noise1.astype(jnp.float32),
      noise2.astype(jnp.float32))
    return new[:, :d], cons[0, :d]


def _cwfl_round_kernel_guard(a_ref, b_ref, m_ref, s_ref, n1_ref, n2_ref,
                             new_ref, cons_ref):
    """:func:`_cwfl_round_kernel` with the fault guard (mirrors
    ``repro.kernels.ref.cwfl_round_ref(..., guard=True)``): sanitize
    non-finite signals to 0 and zero all-dead Ã rows before the consensus
    mix.  Cheap VPU elementwise ops on the already-VMEM-resident blocks;
    the Ã row-sum reduction is (C, K)-tiny and grid-invariant.  Kept as a
    separate kernel so the faults-off trace is byte-identical to the
    unguarded round (origin names + source lines are baked into jaxprs).
    """
    s = s_ref[...].astype(jnp.float32)                       # (K, T)
    a = a_ref[...].astype(jnp.float32)                       # (C, K)
    b = b_ref[...].astype(jnp.float32)                       # (C, C)
    m = m_ref[...].astype(jnp.float32)                       # (K, C)
    s = jnp.where(jnp.isfinite(s), s, 0.0)

    dims = (((1,), (0,)), ((), ()))
    theta_tilde = jax.lax.dot_general(
        a, s, dims, preferred_element_type=jnp.float32)
    theta_tilde = theta_tilde + n1_ref[...].astype(jnp.float32)   # (C, T)
    dead = jnp.sum(jnp.abs(a), axis=1, keepdims=True) <= 0.0
    theta_tilde = jnp.where(dead, 0.0, theta_tilde)
    theta_bar = jax.lax.dot_general(
        b, theta_tilde, dims, preferred_element_type=jnp.float32)
    theta_bar = theta_bar + n2_ref[...].astype(jnp.float32)       # (C, T)
    new = jax.lax.dot_general(
        m, theta_bar, dims, preferred_element_type=jnp.float32)   # (K, T)
    new_ref[...] = new.astype(new_ref.dtype)
    cons_ref[...] = jnp.mean(theta_bar, axis=0, keepdims=True)


def cwfl_round_auto(signals, phase1, noise1, phase2, noise2, broadcast, *,
                    tile: int = DEFAULT_TILE,
                    interpret: Optional[bool] = None,
                    use_pallas: Optional[bool] = None,
                    guard: bool = False):
    """Route one round through the fused kernel when the flat dimension is
    large enough to benefit (``d >= PALLAS_MIN_DIM``), else the jnp
    reference (a single fused XLA computation at small d).  ``guard``
    engages the NaN/dead-row guard on whichever route is taken."""
    from repro.kernels.ref import cwfl_round_ref

    if use_pallas is None:
        use_pallas = signals.shape[1] >= PALLAS_MIN_DIM
    if use_pallas:
        return cwfl_round(signals, phase1, noise1, phase2, noise2,
                          broadcast, tile=tile, interpret=interpret,
                          guard=guard)
    return cwfl_round_ref(signals, phase1, noise1, phase2, noise2, broadcast,
                          guard=guard)


def hbm_bytes_model(K: int, C: int, d: int, itemsize: int = 4) -> dict:
    """Modeled HBM traffic per sync round (weights are O(KC), negligible).

    Both variants must read S (K·d) + the two noise fields (2·C·d) and
    write new (K·d) + consensus (d).  The unfused three-pass round adds a
    write + read of θ̃ (2·C·d) and a write + two reads of θ̄ (3·C·d) —
    5·C·d extra scalars round-tripped through HBM.
    """
    base = d * (2 * K + 2 * C + 1)
    return {
        "fused_bytes": itemsize * base,
        "unfused_bytes": itemsize * (base + 5 * C * d),
        "traffic_ratio": (base + 5 * C * d) / base,
    }
