"""jit'd wrappers bridging model-layout tensors to the Pallas kernels.

These are the public entry points:
  * ``ota_aggregate_op``      — CWFL phase-1 MAC over flattened pytrees
  * ``flash_attention_op``    — (B, S, H, D)-layout attention (model layout)

``interpret=None`` resolves backend-aware: interpret mode off-TPU (this
container validates there), compiled kernels on TPU.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as _fa
from repro.kernels.ota_aggregate import ota_aggregate as _ota
from repro.utils import tree_flatten_vector, tree_unflatten_vector


def ota_aggregate_op(stacked_params, weights, noise_key, noise_std,
                     *, tile: int = 2048, interpret: Optional[bool] = None):
    """CWFL phase 1 over a K-stacked parameter pytree.

    stacked_params: pytree with (K, ...) leaves; weights: (C, K);
    returns a pytree with (C, ...) leaves (per-cluster aggregates).
    """
    K = jax.tree.leaves(stacked_params)[0].shape[0]
    C = weights.shape[0]
    flat = jax.vmap(tree_flatten_vector)(stacked_params)     # (K, d)
    noise = noise_std * jax.random.normal(noise_key, (C, flat.shape[1]),
                                          flat.dtype)
    agg = _ota(flat, weights.astype(flat.dtype), noise, tile=tile,
               interpret=interpret)                          # (C, d)
    template = jax.tree.map(lambda x: x[0], stacked_params)
    return jax.vmap(lambda v: tree_unflatten_vector(v, template))(agg)


def flash_attention_op(q, k, v, *, causal: bool = True, window: int = 0,
                       cap: float = 0.0, block_q: int = 128,
                       block_k: int = 128, interpret: Optional[bool] = None):
    """Model layout: q (B, S, H, D); k, v (B, S, KV, D) -> (B, S, H, D)."""
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    o = _fa(qt, kt, vt, causal=causal, window=window, cap=cap,
            block_q=block_q, block_k=block_k, interpret=interpret)
    return jnp.swapaxes(o, 1, 2)
