"""Pallas TPU kernel: blockwise flash attention (causal / sliding-window /
softcap, GQA-aware).

TPU-native blocking (DESIGN.md §8): grid (B, H, Sq/bq, Skv/bk) with the KV
dimension innermost ("arbitrary" semantics); online-softmax state (m, l, acc)
lives in VMEM scratch across the KV sweep and the output block is written on
the last KV step. Block shapes are MXU-aligned (multiples of 128 on the
lane dim, 8 on sublanes). GQA is handled in the index_map (query head h reads
KV head h // G) — no KV replication in HBM.

The pure-jnp oracle is repro.kernels.ref.flash_attention_ref; the chunked
model implementation (repro.models.attention) uses the same math.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ota_aggregate import resolve_interpret

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               causal: bool, window: int, cap: float, bq: int, bk: int,
               n_kv: int, skv: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)          # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)          # (bk, d)
    d = q.shape[-1]

    s = jax.lax.dot_general(q * (d ** -0.5), k,
                            (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)
    if cap > 0.0:
        s = cap * jnp.tanh(s / cap)

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos < skv                            # pad validity
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                           # (bq, 1)
    m_new = jnp.maximum(m_prev[:, 0], jnp.max(s, axis=-1))[:, None]
    p = jnp.exp(s - m_new)                        # (bq, bk)
    corr = jnp.exp(m_prev - m_new)                # (bq, 1)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)[:, None]
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _finish():
        l = l_ref[...]
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "cap",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    cap: float = 0.0, block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None):
    """q: (B, H, Sq, D); k, v: (B, KV, Skv, D). Returns (B, H, Sq, D).
    ``interpret=None`` resolves backend-aware (interpret off-TPU,
    compiled on TPU)."""
    interpret = resolve_interpret(interpret)
    B, H, Sq, D = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    G = H // KV

    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    sq_p = -(-Sq // bq) * bq
    sk_p = -(-Skv // bk) * bk
    if sq_p != Sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - Sq), (0, 0)))
    if sk_p != Skv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, sk_p - Skv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, sk_p - Skv), (0, 0)))
    n_kv = sk_p // bk

    kernel = functools.partial(
        _fa_kernel, causal=causal, window=window, cap=cap, bq=bq, bk=bk,
        n_kv=n_kv, skv=Skv)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, sq_p // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, sq_p, D), q.dtype),
        scratch_shapes=_vmem_scratch(bq, D),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq]


def _vmem_scratch(bq: int, d: int):
    """VMEM scratch for the (m, l, acc) online-softmax state."""
    from jax.experimental.pallas import tpu as pltpu
    return [pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32)]
