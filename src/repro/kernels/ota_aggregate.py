"""Pallas TPU kernel: fused OTA-MAC aggregation (CWFL phase 1).

The per-round hot-spot of the paper: for every cluster c, the head receives
    y_c = Σ_k W[c,k] · s_k + n_c            (eq. 7/8 after channel inversion)
over the d-dimensional flattened parameter vector. Unfused, this is three
HBM round-trips over (K, d) data (scale, reduce, add-noise); the kernel does
one pass with a VMEM-resident (K, TILE) block per grid step.

TPU-native design notes (DESIGN.md §8): the MAC superposition maps to an
in-register reduction over the K (client) dim; tiles are (8·K, 128·n)-aligned
for the VPU; the weights matrix (C, K) stays fully resident in VMEM (tiny).
Validated in interpret mode against repro.kernels.ref.ota_aggregate_ref.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TILE = 2048


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """``None`` → interpret off-TPU (CPU validation), compiled on TPU.

    Shared by every kernel entry point so TPU callers get the compiled
    kernel by default instead of a silently deoptimized interpreter run.
    """
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _ota_kernel(w_ref, s_ref, n_ref, o_ref):
    """Grid: (C, d // TILE). Blocks:
    w: (1, K) weights row; s: (K, TILE) signals; n/o: (1, TILE)."""
    w = w_ref[...].astype(jnp.float32)          # (1, K)
    s = s_ref[...].astype(jnp.float32)          # (K, TILE)
    n = n_ref[...].astype(jnp.float32)          # (1, TILE)
    acc = jax.lax.dot_general(
        w, s, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)      # (1, TILE)
    o_ref[...] = (acc + n).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def ota_aggregate(signals: jnp.ndarray, weights: jnp.ndarray,
                  noise: jnp.ndarray, *, tile: int = DEFAULT_TILE,
                  interpret: Optional[bool] = None) -> jnp.ndarray:
    """y = weights @ signals + noise, fused.

    signals: (K, d); weights: (C, K); noise: (C, d). Returns (C, d).
    d is padded to a multiple of ``tile`` internally.  ``interpret=None``
    resolves backend-aware (interpret off-TPU, compiled on TPU).
    """
    interpret = resolve_interpret(interpret)
    K, d = signals.shape
    C = weights.shape[0]
    dp = -(-d // tile) * tile
    if dp != d:
        signals = jnp.pad(signals, ((0, 0), (0, dp - d)))
        noise = jnp.pad(noise, ((0, 0), (0, dp - d)))

    out = pl.pallas_call(
        _ota_kernel,
        grid=(C, dp // tile),
        in_specs=[
            pl.BlockSpec((1, K), lambda c, t: (c, 0)),
            pl.BlockSpec((K, tile), lambda c, t: (0, t)),
            pl.BlockSpec((1, tile), lambda c, t: (c, t)),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda c, t: (c, t)),
        out_shape=jax.ShapeDtypeStruct((C, dp), signals.dtype),
        interpret=interpret,
    )(weights, signals, noise)
    return out[:, :d]
