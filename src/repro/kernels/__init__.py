# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
# NOTE: import the fused-round kernel as
# ``from repro.kernels.cwfl_round import cwfl_round`` — no package-level
# re-exports here (the function would shadow its submodule of the same
# name, and eager imports would pull in pallas for every consumer).
