"""Pure-jnp oracles for the Pallas kernels (tests assert_allclose vs these)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ota_aggregate_ref(signals: jnp.ndarray, weights: jnp.ndarray,
                      noise: jnp.ndarray) -> jnp.ndarray:
    """Phase-1 OTA MAC for all clusters at once.

    signals: (K, d) channel-inverted client parameter vectors.
    weights: (C, K) per-(cluster, client) amplitudes (0 for non-members).
    noise:   (C, d) receiver AWGN (pre-generated; the MAC adds it).
    Returns: (C, d) received aggregates  y = W @ S + N.
    """
    return (weights.astype(jnp.float32) @ signals.astype(jnp.float32)
            + noise.astype(jnp.float32)).astype(signals.dtype)


def cwfl_round_ref(signals: jnp.ndarray, phase1: jnp.ndarray,
                   noise1: jnp.ndarray, phase2: jnp.ndarray,
                   noise2: jnp.ndarray, broadcast: jnp.ndarray,
                   guard: bool = False):
    """Three-pass CWFL sync round (the unfused baseline the fused
    ``cwfl_round`` kernel must match bit-for-bit in f32).

    signals: (K, d); phase1: (C, K) Ã; noise1: (C, d); phase2: (C, C) B̃;
    noise2: (C, d); broadcast: (K, C) downlink matrix (membership.T).
    Returns ``(new (K, d) signals.dtype, consensus (d,) f32)``.

    ``guard`` (STATIC flag, fault scenarios — DESIGN.md §Faults): the
    CWFL cousin of the flash-attention "fully-masked rows -> 0" rule
    below.  Non-finite signals are sanitized to 0 *before* the phase-1
    matmul (a quarantined client's zero amplitude still multiplies its
    NaN signal — 0 × NaN = NaN — so masking alone cannot contain it),
    and a fully-masked Ã row (an all-failed cluster) forces its θ̃ row —
    noise included — to exactly 0 instead of the renormalized noise
    blow-up.  Guard-off traces a byte-identical jaxpr.
    """
    s = signals.astype(jnp.float32)
    a = phase1.astype(jnp.float32)
    if guard:
        s = jnp.where(jnp.isfinite(s), s, 0.0)
    theta_tilde = a @ s + noise1.astype(jnp.float32)
    if guard:
        dead = jnp.sum(jnp.abs(a), axis=1, keepdims=True) <= 0.0
        theta_tilde = jnp.where(dead, 0.0, theta_tilde)
    theta_bar = (phase2.astype(jnp.float32) @ theta_tilde
                 + noise2.astype(jnp.float32))
    new = (broadcast.astype(jnp.float32) @ theta_bar).astype(signals.dtype)
    return new, jnp.mean(theta_bar, axis=0)


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        cap: float = 0.0):
    """Exact softmax attention. q: (B, H, Sq, D); k, v: (B, KV, Skv, D)."""
    B, H, Sq, D = q.shape
    KV = k.shape[1]
    G = H // KV
    qg = (q.astype(jnp.float32) * (D ** -0.5)).reshape(B, KV, G, Sq, D)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg, k.astype(jnp.float32))
    if cap > 0.0:
        s = cap * jnp.tanh(s / cap)
    Skv = k.shape[2]
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows -> 0
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, D).astype(q.dtype)
