"""repro.strategies — the pluggable aggregation-strategy registry.

One `Strategy` object per algorithm (CWFL, CWFL-Prox, COTAF, COTAF-Prox,
FedAvg, decentralized) owning setup, the scan-legal per-round state
rebuild, the sync round, the receive-side participation rule, and the
capability flags the engine/sharded layers gate on.  See DESIGN.md
§Strategy-API for the protocol and a worked "add a strategy" example.
"""
from repro.strategies.base import (Strategy, available_strategies,
                                   get_strategy, register_strategy)
from repro.strategies.builtin import (PAPER_MU_PROX, COTAFStrategy,
                                      CWFLStrategy, DecentralizedStrategy,
                                      FedAvgStrategy)
