"""The paper's strategy family, ported onto the Strategy protocol.

* ``cwfl`` / ``cwfl_prox`` — Algorithm 1's clustered two-phase OTA
  aggregation (`repro.core.cwfl`); the prox variant runs the same channel
  with the FedProx local objective (µ_p = 0.1, paper §V).
* ``cotaf`` / ``cotaf_prox`` — the modified-COTAF central-server baseline:
  one shared MAC to the best-connected client (`repro.core.baselines`).
* ``fedavg`` — ideal noiseless server aggregation (upper bound).
* ``decentralized`` — Metropolis–Hastings consensus over G(V, L); absence
  is graph pruning, not MAC masking (isolated nodes keep their params).

Each strategy delegates to the same `repro.core` operators the old
string-dispatch called, in the same order — the port is bit-neutral
(pinned by ``tests/goldens/paper_static_T4_K8.json``).
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, Optional

import jax.numpy as jnp

from repro.core import baselines, clustering as cl, cwfl
from repro.strategies.base import Strategy, register_strategy


def _snr_noise_var(topology, snr_db):
    """Resolved receiver noise variance: the topology's own budget, or the
    variance hitting an overall SNR override (possibly a traced scalar)."""
    from repro.core import channel as ch
    if snr_db is None:
        return topology.noise_var
    return ch.snr_db_to_noise_var(topology.total_power, snr_db)


@dataclasses.dataclass(frozen=True)
class CWFLStrategy(Strategy):
    """Algorithm 1: cluster on SNR, water-fill, two-phase OTA aggregation."""

    supports_client_sharding: ClassVar[bool] = True
    water_fills: ClassVar[bool] = True
    reclusters: ClassVar[bool] = True

    def init(self, topology, key, cfg, snr_db: Optional[float] = None):
        return cwfl.setup(
            topology,
            cwfl.CWFLConfig(num_clusters=cfg.num_clusters, snr_db=snr_db),
            key)

    def state_from_view(self, state0, view, noise_var, *,
                        csi=None, mask=None, plan=None, alive=None):
        del mask, alive   # folded into the round coefficients by aggregate()
        return cwfl.state_from_plan(
            state0.plan if plan is None else plan,
            view.link_gain, state0.total_power, noise_var, csi_perturb=csi)

    def aggregate(self, stacked_params, state, key, mask=None, alive=None):
        # alive engages the dead-cluster row guard in round_coefficients
        # AND the NaN-containment guard in the fused round (a quarantined
        # client's poisoned signal must not reach the MAC matmul).
        return cwfl.aggregate(stacked_params, state, key, mask=mask,
                              alive=alive, guard=alive is not None)

    def receive_mask(self, state, mask, alive=None):
        # Heads are forced present on the transmit side — they ARE the
        # phase-1/2 receivers — so they also keep the aggregate they
        # computed rather than revert to their local params.  A *crashed*
        # head holds nothing: alive limits the forcing.
        return cwfl.participation_weights(state, mask, alive=alive)

    def on_head_failure(self, state0, plan, view, alive, key):
        # Handoff rule (DESIGN.md §Faults): keep live heads; a dead head
        # is replaced by the surviving member with the best within-cluster
        # aggregate link SNR.  Stateless — derived fresh each round from
        # the base plan + alive, so a recovered head resumes automatically.
        del key
        return cl.reelect_heads(state0.plan if plan is None else plan,
                                view.link_snr, alive)

    def recluster(self, view, num_clusters: int, key):
        return cl.make_cluster_plan(view.link_snr, view.adjacency,
                                    num_clusters, key)

    def channel_uses(self, num_clients, num_clusters=None,
                     participants=None):
        # Paper §IV: C OTA intra-cluster slots + C(C−1) directed
        # head→head consensus uses; independent of who shows up (heads
        # are forced present, absent members just thin the superposition).
        del num_clients, participants
        C = num_clusters
        return C * (C - 1) + C

    def telemetry(self, state, *, losses, stacked, new_stacked, consensus,
                  mask=None):
        from repro.obs.telemetry import per_client_dim, \
            stacked_consensus_drift

        plan = state.plan
        counts = jnp.maximum(plan.membership.sum(axis=1), 1.0)
        part = cwfl.participation_weights(state, mask)
        participants = (jnp.asarray(state.num_clients, jnp.float32)
                        if part is None else jnp.sum(part))

        # The exact coefficients this round transmitted with — the eq. (5)
        # precode scales and the phase-1/2 equivalent receiver-noise stds.
        mean_sq = cwfl.per_client_mean_sq(stacked)
        _, eff_std1, _, kappa, _ = cwfl.round_coefficients(
            state, stacked, mask=mask, mean_sq=mean_sq)
        pre = cwfl.precode_scale(state, mean_sq)
        # Per-channel-use power each *member* actually puts on the MAC:
        # amplitude² = (p_k · pre_k)² per unit-power symbol, × E‖θ‖²/d.
        # Heads never cross the channel (virtual clients).
        member = 1.0 - plan.head_mask
        amp2 = (state.client_power / state.total_power) * pre**2
        tx_power = member * amp2 * mean_sq
        if part is not None:
            tx_power = tx_power * part
        d = per_client_dim(stacked)
        return {
            "cluster_loss": (plan.membership @ losses) / counts,
            "participants": participants,
            "consensus_drift": stacked_consensus_drift(
                new_stacked, consensus)[plan.heads],
            "extras": {
                "precode_scale": pre,
                "client_power": state.client_power,
                "tx_power": tx_power,
                "power_budget_frac": jnp.sum(tx_power) / state.total_power,
                "phase1_noise_std": eff_std1,
                "phase2_noise_std": kappa,
                "noise_energy": d * (jnp.sum(eff_std1**2)
                                     + jnp.sum(kappa**2)),
            },
        }


@dataclasses.dataclass(frozen=True)
class COTAFStrategy(Strategy):
    """Modified COTAF: all K clients on ONE MAC to a central server."""

    water_fills: ClassVar[bool] = True

    def init(self, topology, key, cfg, snr_db: Optional[float] = None):
        return baselines.cotaf_setup(topology, key, snr_db=snr_db)

    def state_from_view(self, state0, view, noise_var, *,
                        csi=None, mask=None, plan=None, alive=None):
        del mask, plan
        # Server FAILOVER: selection argmaxes over surviving nodes only,
        # so a crashed server hands the role to the best live node.
        return baselines.cotaf_state_from_gains(
            view.link_gain, state0.total_power, noise_var, csi_perturb=csi,
            alive=alive)

    def aggregate(self, stacked_params, state, key, mask=None, alive=None):
        del alive   # failover happened in state_from_view; dead nodes are
        # already masked off the MAC by the engine's tx fold.
        return baselines.cotaf_aggregate(stacked_params, state, key,
                                         mask=mask)

    def receive_mask(self, state, mask, alive=None):
        # Same receiver rule as CWFL heads: the server holds the
        # aggregate, so it keeps it.  Failover already guarantees the
        # server is alive whenever any node is, so alive needs no extra
        # fold here.
        del alive
        return baselines.cotaf_participation(state, mask)

    def channel_uses(self, num_clients, num_clusters=None,
                     participants=None):
        # One shared OTA MAC to the server, however many transmit on it.
        del num_clients, num_clusters, participants
        return 1

    def telemetry(self, state, *, losses, stacked, new_stacked, consensus,
                  mask=None):
        t = super().telemetry(state, losses=losses, stacked=stacked,
                              new_stacked=new_stacked, consensus=consensus,
                              mask=mask)
        part = baselines.cotaf_participation(state, mask)
        if part is not None:
            t["participants"] = jnp.sum(part)
        t["extras"] = {
            "server": (jnp.asarray(-1.0, jnp.float32) if state.server is None
                       else state.server.astype(jnp.float32)),
            "client_power": state.client_power,
            "mac_noise_std": (state.noise_std
                              / jnp.sqrt(state.total_power)),
        }
        return t


@dataclasses.dataclass(frozen=True)
class FedAvgStrategy(Strategy):
    """Ideal noiseless server aggregation (eq. 2) — stateless."""

    def init(self, topology, key, cfg, snr_db: Optional[float] = None):
        del topology, key, cfg, snr_db
        return None

    def state_from_view(self, state0, view, noise_var, *,
                        csi=None, mask=None, plan=None, alive=None):
        del state0, view, noise_var, csi, mask, plan, alive
        return None

    def aggregate(self, stacked_params, state, key, mask=None, alive=None):
        del state, key, alive   # dead nodes arrive masked (engine tx fold)
        return baselines.fedavg_aggregate(stacked_params, weights=mask)


@dataclasses.dataclass(frozen=True)
class DecentralizedStrategy(Strategy):
    """Fully-decentralized Metropolis–Hastings consensus over G(V, L)."""

    needs_graph: ClassVar[bool] = True

    def init(self, topology, key, cfg, snr_db: Optional[float] = None):
        return baselines.decentralized_setup(topology, key, snr_db=snr_db)

    def state_from_view(self, state0, view, noise_var, *,
                        csi=None, mask=None, plan=None, alive=None):
        del csi, plan, alive   # dead nodes arrive masked (engine tx fold)
        # Absence is graph pruning, not MAC masking: Metropolis weights
        # give isolated (absent/crashed) nodes W(k,k)=1, so they keep
        # their parameters with zero noise — re-Metropolization over the
        # pruned graph IS the decentralized fault handoff.
        adj = view.adjacency
        if mask is not None:
            mb = mask > 0
            adj = adj & mb[:, None] & mb[None, :]
        return baselines.decentralized_state_from_graph(
            adj, state0.total_power, noise_var)

    def aggregate(self, stacked_params, state, key, mask=None, alive=None):
        del mask, alive   # already pruned into the Metropolis graph
        return baselines.decentralized_aggregate(stacked_params, state, key)

    def receive_mask(self, state, mask, alive=None):
        # The mixing matrix already encodes absences — no receive-side
        # fold (and no sync-skip guard) on top.
        del alive
        return None

    def channel_uses(self, num_clients, num_clusters=None,
                     participants=None):
        # Eq. 3's full-gossip cost: every participating node transmits to
        # every other — P(P−1) directed uses (K(K−1) when unmasked).
        del num_clusters
        p = num_clients if participants is None else participants
        return p * (p - 1)

    def telemetry(self, state, *, losses, stacked, new_stacked, consensus,
                  mask=None):
        t = super().telemetry(state, losses=losses, stacked=stacked,
                              new_stacked=new_stacked, consensus=consensus,
                              mask=mask)
        W = state.mixing
        off = W * (1.0 - jnp.eye(W.shape[0]))
        t["extras"] = {
            "active_links": jnp.sum(off > 0).astype(jnp.float32),
            "mean_self_weight": jnp.mean(jnp.diag(W)),
            "receive_noise_std": jnp.sqrt(jnp.sum(off**2, axis=1)) * (
                state.noise_std / jnp.sqrt(state.total_power)),
        }
        return t


# Paper §V's FedProx coefficient for the *-Prox curves.
PAPER_MU_PROX = 0.1

register_strategy("cwfl", CWFLStrategy(name="cwfl"))
register_strategy("cotaf", COTAFStrategy(name="cotaf"))
register_strategy("fedavg", FedAvgStrategy(name="fedavg"))
register_strategy("decentralized", DecentralizedStrategy(name="decentralized"))
# CWFL-Prox / COTAF-Prox are headline curves of the paper (Fig. 2 non-IID):
# same channel, proximal local objective — first-class names, not a
# mu_prox side-channel.
register_strategy("cwfl_prox",
                  CWFLStrategy(name="cwfl_prox", mu_prox=PAPER_MU_PROX))
register_strategy("cotaf_prox",
                  COTAFStrategy(name="cotaf_prox", mu_prox=PAPER_MU_PROX))
