"""The paper's strategy family, ported onto the Strategy protocol.

* ``cwfl`` / ``cwfl_prox`` — Algorithm 1's clustered two-phase OTA
  aggregation (`repro.core.cwfl`); the prox variant runs the same channel
  with the FedProx local objective (µ_p = 0.1, paper §V).
* ``cotaf`` / ``cotaf_prox`` — the modified-COTAF central-server baseline:
  one shared MAC to the best-connected client (`repro.core.baselines`).
* ``fedavg`` — ideal noiseless server aggregation (upper bound).
* ``decentralized`` — Metropolis–Hastings consensus over G(V, L); absence
  is graph pruning, not MAC masking (isolated nodes keep their params).

Each strategy delegates to the same `repro.core` operators the old
string-dispatch called, in the same order — the port is bit-neutral
(pinned by ``tests/goldens/paper_static_T4_K8.json``).
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, Optional

from repro.core import baselines, clustering as cl, cwfl
from repro.strategies.base import Strategy, register_strategy


def _snr_noise_var(topology, snr_db):
    """Resolved receiver noise variance: the topology's own budget, or the
    variance hitting an overall SNR override (possibly a traced scalar)."""
    from repro.core import channel as ch
    if snr_db is None:
        return topology.noise_var
    return ch.snr_db_to_noise_var(topology.total_power, snr_db)


@dataclasses.dataclass(frozen=True)
class CWFLStrategy(Strategy):
    """Algorithm 1: cluster on SNR, water-fill, two-phase OTA aggregation."""

    supports_client_sharding: ClassVar[bool] = True
    water_fills: ClassVar[bool] = True
    reclusters: ClassVar[bool] = True

    def init(self, topology, key, cfg, snr_db: Optional[float] = None):
        return cwfl.setup(
            topology,
            cwfl.CWFLConfig(num_clusters=cfg.num_clusters, snr_db=snr_db),
            key)

    def state_from_view(self, state0, view, noise_var, *,
                        csi=None, mask=None, plan=None):
        del mask   # folded into the round coefficients by aggregate()
        return cwfl.state_from_plan(
            state0.plan if plan is None else plan,
            view.link_gain, state0.total_power, noise_var, csi_perturb=csi)

    def aggregate(self, stacked_params, state, key, mask=None):
        return cwfl.aggregate(stacked_params, state, key, mask=mask)

    def receive_mask(self, state, mask):
        # Heads are forced present on the transmit side — they ARE the
        # phase-1/2 receivers — so they also keep the aggregate they
        # computed rather than revert to their local params.
        return cwfl.participation_weights(state, mask)

    def recluster(self, view, num_clusters: int, key):
        return cl.make_cluster_plan(view.link_snr, view.adjacency,
                                    num_clusters, key)


@dataclasses.dataclass(frozen=True)
class COTAFStrategy(Strategy):
    """Modified COTAF: all K clients on ONE MAC to a central server."""

    water_fills: ClassVar[bool] = True

    def init(self, topology, key, cfg, snr_db: Optional[float] = None):
        return baselines.cotaf_setup(topology, key, snr_db=snr_db)

    def state_from_view(self, state0, view, noise_var, *,
                        csi=None, mask=None, plan=None):
        del mask, plan
        return baselines.cotaf_state_from_gains(
            view.link_gain, state0.total_power, noise_var, csi_perturb=csi)

    def aggregate(self, stacked_params, state, key, mask=None):
        return baselines.cotaf_aggregate(stacked_params, state, key,
                                         mask=mask)

    def receive_mask(self, state, mask):
        # Same receiver rule as CWFL heads: the server holds the
        # aggregate, so it keeps it.
        return baselines.cotaf_participation(state, mask)


@dataclasses.dataclass(frozen=True)
class FedAvgStrategy(Strategy):
    """Ideal noiseless server aggregation (eq. 2) — stateless."""

    def init(self, topology, key, cfg, snr_db: Optional[float] = None):
        del topology, key, cfg, snr_db
        return None

    def state_from_view(self, state0, view, noise_var, *,
                        csi=None, mask=None, plan=None):
        del state0, view, noise_var, csi, mask, plan
        return None

    def aggregate(self, stacked_params, state, key, mask=None):
        del state, key
        return baselines.fedavg_aggregate(stacked_params, weights=mask)


@dataclasses.dataclass(frozen=True)
class DecentralizedStrategy(Strategy):
    """Fully-decentralized Metropolis–Hastings consensus over G(V, L)."""

    needs_graph: ClassVar[bool] = True

    def init(self, topology, key, cfg, snr_db: Optional[float] = None):
        return baselines.decentralized_setup(topology, key, snr_db=snr_db)

    def state_from_view(self, state0, view, noise_var, *,
                        csi=None, mask=None, plan=None):
        del csi, plan
        # Absence is graph pruning, not MAC masking: Metropolis weights
        # give isolated (absent) nodes W(k,k)=1, so they keep their
        # parameters with zero noise.
        adj = view.adjacency
        if mask is not None:
            mb = mask > 0
            adj = adj & mb[:, None] & mb[None, :]
        return baselines.decentralized_state_from_graph(
            adj, state0.total_power, noise_var)

    def aggregate(self, stacked_params, state, key, mask=None):
        del mask   # already pruned into the Metropolis graph
        return baselines.decentralized_aggregate(stacked_params, state, key)

    def receive_mask(self, state, mask):
        # The mixing matrix already encodes absences — no receive-side
        # fold (and no sync-skip guard) on top.
        return None


# Paper §V's FedProx coefficient for the *-Prox curves.
PAPER_MU_PROX = 0.1

register_strategy("cwfl", CWFLStrategy(name="cwfl"))
register_strategy("cotaf", COTAFStrategy(name="cotaf"))
register_strategy("fedavg", FedAvgStrategy(name="fedavg"))
register_strategy("decentralized", DecentralizedStrategy(name="decentralized"))
# CWFL-Prox / COTAF-Prox are headline curves of the paper (Fig. 2 non-IID):
# same channel, proximal local objective — first-class names, not a
# mu_prox side-channel.
register_strategy("cwfl_prox",
                  CWFLStrategy(name="cwfl_prox", mu_prox=PAPER_MU_PROX))
register_strategy("cotaf_prox",
                  COTAFStrategy(name="cotaf_prox", mu_prox=PAPER_MU_PROX))
