"""The Strategy protocol + registry (DESIGN.md §Strategy-API).

The paper's contribution is a *family* of aggregation strategies (CWFL,
CWFL-Prox, the COTAF-style central server, fully-decentralized consensus),
and every layer of this repo used to re-dispatch on the strategy *name*:
`training.federated.STRATEGIES` held bare ``(setup, aggregate)`` tuples,
`sim/engine.py` re-branched ``if cfg.strategy == "cwfl" / "cotaf" / ...``
to rebuild per-round states and pick receive-side rules, and
`sim/sharded.py` hard-rejected everything but ``"cwfl"``.  This module is
the single seam that replaces all of it: a :class:`Strategy` object owns
the whole per-strategy surface —

* ``init(topology, key, cfg, snr_db)``      — offline setup → State;
* ``state_from_view(state0, view, noise_var, ...)`` — the per-round
  scan-legal rebuild from a `repro.sim.processes.ChannelView` (pure jnp,
  traces under ``lax.scan``/``vmap``);
* ``aggregate(stacked, state, key, mask)``  — one sync round;
* ``receive_mask(state, mask)``             — the heads/server
  forced-present downlink rule (``None`` ⇒ the aggregate already encodes
  absences, e.g. decentralized's pruned Metropolis graph);
* capability flags (``supports_client_sharding``, ``needs_graph``,
  ``water_fills``, ``reclusters``) that gate the sharded/simulated
  execution paths instead of name string checks;
* observability hooks (``channel_uses``, ``telemetry``) — the per-round
  channel-use count and the strategy-internal telemetry pytree the
  `repro.obs` subsystem records when the engine runs with telemetry
  enabled (DESIGN.md §Obs).

``register_strategy(name)`` adds a strategy to the registry every
front door resolves through: ``FLConfig.strategy``, ``Scenario.strategy``
(`repro.sim.scenarios`), and ``examples/run_scenario.py --strategy``.
Adding a new OTA variant (hierarchical clustering à la arXiv 2207.09232,
heterogeneous-data precoding à la Sery et al.) is one subclass + one
``register_strategy`` call — no engine/sharded/training edits.
"""
from __future__ import annotations

import dataclasses
from typing import Any, ClassVar, Optional

State = Any   # strategy state: any registered pytree (None for stateless)


@dataclasses.dataclass(frozen=True)
class Strategy:
    """One aggregation strategy: offline setup, per-round state rebuild,
    the sync round itself, and the receive-side participation rule.

    Instances are frozen dataclasses so a *variant* is just another
    instance of the same class (``CWFLStrategy(name="cwfl_prox",
    mu_prox=0.1)`` — same channel math, proximal local objective).
    Capability flags are ``ClassVar``s: they describe the *algorithm*,
    not the instance.
    """

    name: str
    #: Default FedProx µ_p for the local objective (paper §V).  0 = plain
    #: SGD.  An explicit ``FLConfig.mu_prox > 0`` overrides it — see
    #: :meth:`effective_mu_prox`; prox variants (``cwfl_prox``,
    #: ``cotaf_prox``) set the paper's 0.1 here so they are first-class
    #: named strategies rather than a config side-channel.
    mu_prox: float = 0.0

    # -- capability flags ---------------------------------------------------
    #: The client-sharded trajectory (`repro.sim.sharded.
    #: run_rounds_client_sharded`) implements this strategy's sync as a
    #: mesh collective.
    supports_client_sharding: ClassVar[bool] = False
    #: The per-round state depends on the connectivity graph
    #: (``ChannelView.adjacency``), not only on link gains.
    needs_graph: ClassVar[bool] = False
    #: Power is water-filled from channel estimates ⇒ imperfect CSI
    #: (`repro.sim.processes.csi_perturbation`) perturbs this strategy.
    water_fills: ClassVar[bool] = False
    #: The state carries a cluster plan that periodic on-device
    #: re-clustering (`Scenario.recluster_every`) can replace.
    reclusters: ClassVar[bool] = False

    # -- the protocol -------------------------------------------------------
    def init(self, topology, key, cfg, snr_db: Optional[float] = None
             ) -> State:
        """Offline setup (cluster, water-fill, budget noise) → State.

        ``cfg`` is the `FLConfig` (only strategy-relevant fields such as
        ``num_clusters`` are read); ``snr_db`` is the *resolved* overall
        SNR — it may be a traced scalar (a vmapped Monte-Carlo SNR axis)
        and therefore overrides ``cfg.snr_db``; ``None`` keeps the
        topology's own noise budget.
        """
        raise NotImplementedError

    def state_from_view(self, state0: State, view, noise_var, *,
                        csi=None, mask=None, plan=None, alive=None) -> State:
        """Rebuild the round state from a channel view — the scan-legal
        per-round half of :meth:`init` (pure jnp; ``noise_var`` may be a
        tracer).

        ``state0``: the :meth:`init` state (source of statics such as
        ``total_power`` and the offline cluster plan); ``csi``: optional
        (K,) multiplicative water-filling-gain perturbation (imperfect
        CSI — only meaningful when :attr:`water_fills`); ``mask``:
        optional (K,) {0,1} participation — only graph-based strategies
        (:attr:`needs_graph`) fold it here, by pruning edges; everyone
        else folds it in :meth:`aggregate`; ``plan``: optional
        re-clustered plan (:meth:`recluster`) replacing ``state0``'s;
        ``alive``: optional (K,) {0,1} node-up vector (fault scenarios,
        DESIGN.md §Faults) — distinct from ``mask`` (a fading/scheduling
        absence is transient; a *dead* node cannot serve as a receiver),
        strategies with infrastructure roles fail them over here (COTAF
        re-elects its server).  ``alive=None`` must trace a byte-identical
        jaxpr to the pre-fault protocol.
        """
        raise NotImplementedError

    def aggregate(self, stacked_params, state: State, key, mask=None,
                  alive=None):
        """One sync round on a K-stacked pytree.  Returns
        ``(new_stacked_params, consensus)``.  ``mask`` is the raw (K,)
        {0,1} participation (transmit side; forced-present rules are the
        strategy's own business) — strategies that already folded it into
        ``state`` (see :meth:`state_from_view`) ignore it here.
        ``alive`` is the fault plane's (K,) node-up vector: unlike a
        masked-out client, a dead node is also no *receiver*, so
        strategies must additionally kill dead aggregation rows (CWFL's
        dead-cluster guard) and engage their numeric guards
        (``alive is not None`` ⇒ quarantined-NaN containment).
        """
        raise NotImplementedError

    def receive_mask(self, state: State, mask, alive=None):
        """(K,) effective *receive*-side participation for one masked
        round: which clients adopt the broadcast aggregate (1) vs keep
        their locally-trained params (0).  Nodes the aggregation forces
        present (CWFL cluster-heads, the COTAF server — they *hold* the
        aggregate) must stay 1 even when masked out.  ``alive`` limits
        that forcing to nodes that are actually up — a *crashed* head
        holds nothing (DESIGN.md §Faults).  Return ``None`` when the
        aggregate already encodes absences (decentralized: isolated
        nodes get ``W(k,k)=1``) — the engine then applies no
        receive-side fold at all.
        """
        del alive
        return mask

    def on_head_failure(self, state0: State, plan, view, alive, key):
        """Fault-plane handoff hook: repair the round's infrastructure
        assignment after node crashes, *before* :meth:`state_from_view`
        rebuilds the round state (DESIGN.md §Faults).

        ``plan`` is the round's current cluster plan (the `lax.cond`
        recluster output, or ``None`` for strategies without one);
        ``alive`` the (K,) {0,1} node-up vector.  Only called on the
        fault path (never when ``Scenario.faults.is_trivial``), every
        round — implementations must be scan-legal pure jnp and cheap
        when nothing failed.  Default: no infrastructure to repair —
        return ``plan`` unchanged.  CWFL re-elects dead cluster-heads
        (`repro.core.clustering.reelect_heads`); COTAF's server failover
        rides :meth:`state_from_view` instead (its server is re-derived
        from gains each round anyway).
        """
        del state0, view, alive, key
        return plan

    def recluster(self, view, num_clusters: int, key):
        """Re-derive the cluster plan from a channel view (only called
        when :attr:`reclusters`; `lax.cond`-gated inside the scan)."""
        raise NotImplementedError(
            f"{type(self).__name__} has no cluster plan to rebuild")

    # -- observability hooks (repro.obs, DESIGN.md §Obs) --------------------
    def channel_uses(self, num_clients: int,
                     num_clusters: Optional[int] = None,
                     participants=None):
        """OTA channel uses (MAC slots) one sync round consumes — the
        quantity `repro.obs.ledger` accumulates and the paper's Fig. 4
        communication-cost axis counts.  ``participants`` may be a traced
        scalar (masked rounds); the default is an orchestrator-free genie
        (FedAvg): zero uses.
        """
        return 0

    def telemetry(self, state: State, *, losses, stacked, new_stacked,
                  consensus, mask=None) -> dict:
        """Strategy-internal round telemetry (pure jnp, scan/vmap-legal):
        ``{"cluster_loss": (C',), "participants": scalar,
        "consensus_drift": (C',), "extras": {str: array}}`` — shapes fixed
        across rounds so the pytree rides `lax.scan`.  The default reports
        a single global "cluster": mean loss, mask-summed participation,
        mean model drift ‖θ_k − θ̄‖.  Strategies with real aggregation
        internals (CWFL's precoding scales and injected-noise energy,
        COTAF's server, decentralized's active links) override and extend
        ``extras``.

        ``losses`` is the engine's (K,) per-client TELEMETRY loss — a
        full-shard eval on the post-local-training params, freshly
        computed for the observation plane (the engine must not hand the
        hook its minibatch loss buffer: an extra reduction over it
        changes XLA's fusion of the round's own mean and perturbs the
        reported train_loss by ulps — see `repro.sim.engine`).
        ``stacked``/``new_stacked`` are the pre-/post-sync parameter
        stacks; ``consensus`` the post-sync global model.
        """
        import jax.numpy as jnp

        from repro.obs.telemetry import stacked_consensus_drift

        num_clients = losses.shape[0]
        participants = (jnp.asarray(num_clients, jnp.float32) if mask is None
                        else jnp.sum(mask).astype(jnp.float32))
        drift = jnp.mean(stacked_consensus_drift(new_stacked, consensus))
        return {
            "cluster_loss": jnp.mean(losses)[None],
            "participants": participants,
            "consensus_drift": drift[None],
            "extras": {},
        }

    def effective_mu_prox(self, cfg_mu: float) -> float:
        """FedProx µ_p for the local runner: an explicit per-run
        ``FLConfig.mu_prox`` wins; otherwise the strategy default."""
        return cfg_mu if cfg_mu > 0 else self.mu_prox


# ---------------------------------------------------------------------------
# Registry.
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Strategy] = {}


def register_strategy(name: str, strategy: Optional[Strategy] = None, *,
                      replace: bool = False):
    """Register ``strategy`` under ``name``.

    Two forms::

        register_strategy("cwfl", CWFLStrategy(name="cwfl"))

        @register_strategy("my_ota")          # decorator on a Strategy
        class MyOTAStrategy(Strategy):        # subclass: instantiated
            ...                               # with name=<name>

    ``replace=True`` allows overwriting (tests, experiment sweeps);
    silent shadowing of a registered name is otherwise an error.
    """

    def _register(obj):
        strat = obj(name=name) if isinstance(obj, type) else obj
        if not isinstance(strat, Strategy):
            raise TypeError(
                f"register_strategy needs a Strategy (or Strategy "
                f"subclass); got {type(strat).__name__}")
        if name in _REGISTRY and not replace:
            raise ValueError(
                f"strategy {name!r} is already registered "
                f"({type(_REGISTRY[name]).__name__}); pass replace=True "
                f"to overwrite")
        _REGISTRY[name] = strat
        return obj

    if strategy is None:
        return _register
    return _register(strategy)


def get_strategy(name) -> Strategy:
    """Resolve a strategy by name (or pass a `Strategy` instance through).

    The ONE place strategy names are validated — every front door
    (`FLConfig.strategy` via the engine, `Scenario.strategy`,
    ``run_scenario.py --strategy``) funnels through here, so the error
    message always lists the full current registry, including strategies
    registered by downstream code.
    """
    if isinstance(name, Strategy):
        return name
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown strategy {name!r}; "
                       f"choose from {available_strategies()}") from None


def available_strategies() -> list[str]:
    """Sorted names of every registered strategy."""
    return sorted(_REGISTRY)
