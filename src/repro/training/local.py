"""Per-client local training between sync rounds (eq. 2 top row).

``make_local_runner`` builds a jit-able function that runs E epochs of
mini-batch SGD on ONE client's shard; the federated engine vmaps it over the
stacked K-client axis.  FedProx (paper §V) wraps the loss with the proximal
term  f_k^p(θ) = f_k(θ) + (µ_p/2)‖θ − θ_g‖²  against the latest global sync.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def fedprox_wrap(loss_fn: Callable, mu_prox: float) -> Callable:
    """loss(params, x, y) -> loss + (µ_p/2)·‖params − global‖² (paper §V)."""

    def prox_loss(params, x, y, global_params):
        base = loss_fn(params, x, y)
        sq = sum(jnp.sum(jnp.square(p.astype(jnp.float32) -
                                    g.astype(jnp.float32)))
                 for p, g in zip(jax.tree.leaves(params),
                                 jax.tree.leaves(global_params)))
        return base + 0.5 * mu_prox * sq

    return prox_loss


def make_local_runner(loss_fn: Callable, optimizer, batch_size: int,
                      local_steps: int, mu_prox: float = 0.0):
    """Returns ``run(params, opt_state, x, y, key) -> (params, opt_state, loss)``
    performing ``local_steps`` minibatch-SGD steps on one client's shard.

    ``local_steps`` = E · (N_k // batch_size) for E epochs. Batches are drawn
    by random index sampling (with replacement across steps — standard for
    vmapped FL simulators; per-epoch permutation costs O(N log N) per client).
    """
    base_loss = loss_fn
    prox = mu_prox > 0.0
    if prox:
        prox_loss = fedprox_wrap(loss_fn, mu_prox)
        grad_fn = jax.value_and_grad(prox_loss)
    else:
        grad_fn = jax.value_and_grad(base_loss)

    def run(params, opt_state, x, y, key):
        global_params = params  # snapshot at sync = θ_g for FedProx

        def step(carry, k):
            p, s = carry
            idx = jax.random.randint(k, (batch_size,), 0, x.shape[0])
            if prox:
                loss, grads = grad_fn(p, x[idx], y[idx], global_params)
            else:
                loss, grads = grad_fn(p, x[idx], y[idx])
            updates, s = optimizer.update(grads, s, p)
            p = jax.tree.map(jnp.add, p, updates)
            return (p, s), loss

        keys = jax.random.split(key, local_steps)
        (params, opt_state), losses = jax.lax.scan(step, (params, opt_state),
                                                   keys)
        return params, opt_state, jnp.mean(losses)

    return run
