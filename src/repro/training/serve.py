"""Serving glue: cache capacity management and a simple batched decode loop.

``pad_caches`` converts prefill-produced caches (length = prompt) into
fixed-capacity decode caches:
  * full-attention layers: zero-pad the time axis to ``cache_len``;
  * sliding-window layers: re-order the last W entries into ring-buffer
    layout (slot j holds the newest position p ≡ j (mod W)).
SSM/xLSTM states are size-invariant and pass through unchanged.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tfm
from repro.models.config import ArchConfig


def _pad_time(x, target):
    pad = target - x.shape[1]
    if pad <= 0:
        return x
    return jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))


def _ring_order(S: int, W: int) -> np.ndarray:
    """Index map: ring slot j <- absolute position (newest p ≡ j mod W)."""
    j = np.arange(W)
    p = S - 1 - ((S - 1 - j) % W)
    return p


def pad_caches(caches, cfg: ArchConfig, cache_len: int, prompt_len: int):
    """Prefill caches -> decode caches of fixed capacity."""
    out = {}
    for i, spec in enumerate(cfg.pattern):
        c = caches[f"b{i}"]["mixer"]
        if spec.mixer == "attn":
            W = min(cache_len, spec.window) if spec.window > 0 else cache_len
            if spec.window > 0 and prompt_len >= W:
                idx = jnp.asarray(_ring_order(prompt_len, W))
                c = {"k": c["k"][:, :, idx], "v": c["v"][:, :, idx]}
            else:
                c = {"k": _pad_time_stacked(c["k"], W),
                     "v": _pad_time_stacked(c["v"], W)}
        out[f"b{i}"] = {"mixer": c}
    return out


def _pad_time_stacked(x, target):
    """x: (periods, B, S, ...) — pad axis 2."""
    pad = target - x.shape[2]
    if pad <= 0:
        return x
    return jnp.pad(x, ((0, 0), (0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 3))


def apply_cache_deltas(caches, deltas, pos, cfg: ArchConfig):
    """Engine-side cache write: scatter each attention layer's K/V delta at
    ``pos`` (ring layers: pos % W); recurrent states are replaced whole."""
    out = {}
    for i, spec in enumerate(cfg.pattern):
        c = caches[f"b{i}"]["mixer"]
        d = deltas[f"b{i}"]["mixer"]
        if spec.mixer == "attn":
            W = c["k"].shape[2]                    # (periods, B, W, KV, hd)
            idx = (pos % W if spec.window > 0 and W <= spec.window
                   else pos).astype(jnp.int32)
            zero = jnp.zeros((), jnp.int32)
            new = {
                "k": jax.lax.dynamic_update_slice(
                    c["k"], d["k_new"][:, :, None] if d["k_new"].ndim == 4
                    else d["k_new"], (zero, zero, idx, zero, zero)),
                "v": jax.lax.dynamic_update_slice(
                    c["v"], d["v_new"][:, :, None] if d["v_new"].ndim == 4
                    else d["v_new"], (zero, zero, idx, zero, zero)),
            }
            out[f"b{i}"] = {"mixer": new}
        else:
            out[f"b{i}"] = {"mixer": d}            # full recurrent state
    return out


def greedy_decode(params, batch, cfg: ArchConfig, num_tokens: int,
                  cache_len: Optional[int] = None):
    """Prefill the prompt then greedily decode ``num_tokens`` tokens.

    Returns (tokens (B, num_tokens), last_logits).
    """
    prompt_len = batch["tokens"].shape[1]
    if cfg.frontend == "vision_stub":
        prompt_len += cfg.prefix_tokens
    cache_len = cache_len or (prompt_len + num_tokens)

    logits, caches = tfm.prefill(params, batch, cfg)
    caches = pad_caches(caches, cfg, cache_len, prompt_len)

    enc_kv = None
    if cfg.frontend == "audio_stub":
        enc_out = tfm._encode_audio(params, batch, cfg)
        enc_kv = tfm.encoder_kv(tfm._first_cross_params(params, cfg),
                                enc_out, cfg)

    def body(carry, _):
        tok, caches, pos, logits = carry
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        logits, deltas = tfm.decode_step(params, nxt, caches, pos, cfg,
                                         enc_kv=enc_kv)
        caches = apply_cache_deltas(caches, deltas, pos, cfg)
        return (nxt, caches, pos + 1, logits), nxt[:, 0]

    carry = (batch["tokens"][:, -1:], caches,
             jnp.asarray(prompt_len, jnp.int32), logits)
    (_, _, _, last_logits), toks = jax.lax.scan(body, carry, None,
                                                length=num_tokens)
    return jnp.moveaxis(toks, 0, 1), last_logits
