from repro.training.local import make_local_runner, fedprox_wrap
# STRATEGIES is a deprecated read-only view of repro.strategies (one
# release); new code resolves strategies via repro.strategies.get_strategy.
from repro.training.federated import FLConfig, run_federated, STRATEGIES
