from repro.training.local import make_local_runner, fedprox_wrap
from repro.training.federated import FLConfig, run_federated, STRATEGIES
