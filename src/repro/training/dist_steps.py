"""Distributed (production-mesh) train / prefill / decode step builders.

Each builder returns ``(fn, args_shape_structs, in_shardings)`` ready for
``jax.jit(fn, in_shardings=...).lower(*args).compile()`` — the dry-run path.
``args`` are ShapeDtypeStructs: nothing is ever allocated.

Shard mode (default): one FSDP+TP-sharded model copy; CWFL enters as
(a) per-example consensus loss weights and (b) post-backward channel noise
(see repro.dist.fl_integration). Replica mode: clients are data ranks with
stacked per-client parameters and the paper's Algorithm-1 aggregation
(repro.core.cwfl) applied verbatim across the client axis.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import cwfl as cwfl_core
from repro.dist import fl_integration as fli
from repro.dist import sharding_rules as sr
from repro.models import transformer as tfm
from repro.models.config import ArchConfig, InputShape
from repro.models.inputs import prefill_batch_specs, train_batch_specs
from repro.optim import sgd


def param_shapes(cfg: ArchConfig):
    return jax.eval_shape(
        lambda k: tfm.init_params(k, cfg),
        jax.ShapeDtypeStruct((2,), jnp.uint32))


def _weighted_ce(logits, labels, ex_weights):
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    per_ex = jnp.mean(lse - gold, axis=-1)              # (B,)
    return jnp.mean(per_ex * ex_weights)


def auto_microbatches(cfg: ArchConfig, shape: InputShape, mesh,
                      budget_bytes: float = 2e9) -> int:
    """Gradient-accumulation factor M so that per-device saved remat inputs
    (L × (B/M/dp) × S × (d/tp) × 2 bytes) fit the activation budget."""
    import math
    dp = math.prod(mesh.shape[a] for a in ("pod", "data")
                   if a in mesh.axis_names)
    tp = mesh.shape.get("model", 1)
    d_sh = cfg.d_model // tp if cfg.d_model % tp == 0 else cfg.d_model
    B, S = shape.global_batch, shape.seq_len
    per_m1 = cfg.num_layers * max(B // dp, 1) * S * d_sh * 2
    # CE logits are (B/dp, S, V) in bf16+f32 on each device (vocab is not
    # reliably divisible by the model axis): bound them by microbatching.
    per_m1 = max(per_m1, max(B // dp, 1) * S * cfg.vocab_size * 6)
    if cfg.num_experts > 0:
        # expert-parallel dispatch buffers (buf + h transients, E over dp):
        # tokens/M · k · cf · d · 2 bytes · 2 buffers / dp per device
        per_m1 = max(per_m1,
                     B * S * cfg.top_k * cfg.capacity_factor
                     * cfg.d_model * 2 * 2 / dp)
    m = 1
    max_m = max(B // dp, 1)
    while per_m1 / m > budget_bytes and m < max_m:
        m *= 2
    return min(m, max_m)


def make_train_step(cfg: ArchConfig, shape: InputShape, mesh,
                    plan: Optional[fli.FLPlan] = None, lr: float = 1e-3,
                    microbatches: Optional[int] = None,
                    accum_dtype=jnp.float32, ce_mode: str = "gather"):
    """Shard-mode train step: CWFL consensus weighting + channel noise,
    gradient accumulation over M microbatches (auto-sized to the activation
    budget), SGD (the paper's optimizer).

    ``accum_dtype``: microbatch-gradient accumulator dtype. bfloat16 halves
    the scan-carry footprint; with CWFL the injected channel-noise floor
    (Theorem 1's Q₂) dominates bf16 rounding, so this is a principled
    memory/precision trade recorded in EXPERIMENTS.md §Perf."""
    optimizer = sgd(lr)
    B = shape.global_batch
    M = microbatches if microbatches is not None else auto_microbatches(
        cfg, shape, mesh)
    assert B % M == 0, (B, M)
    if plan is not None:
        ex_w = jnp.asarray(plan.example_weights(B))
        noise_std = plan.noise_std
    else:
        ex_w = jnp.ones((B,), jnp.float32)
        noise_std = 0.0

    def loss_fn(params, batch, w):
        logits, aux = tfm.forward(params, batch, cfg)
        if cfg.frontend == "vision_stub":
            logits = logits[:, cfg.prefix_tokens:]
        if ce_mode == "resharded" and cfg.act_spec is not None:
            # §Perf: batch-shard the logits before CE so logsumexp and the
            # gold-logit gather stay device-local (all-to-all of the logits
            # instead of an all-gather over vocab shards: ~tp× less traffic).
            logits = jax.lax.with_sharding_constraint(
                logits, P(cfg.act_spec[0], None, None))
        ce = _weighted_ce(logits, batch["labels"], w)
        return ce + cfg.router_aux_weight * aux, ce

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(params, opt_state, batch, noise_key):
        if M == 1:
            (loss, ce), grads = grad_fn(params, batch, ex_w)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape((M, x.shape[0] // M) + x.shape[1:]),
                batch)
            w_mb = ex_w.reshape(M, B // M)

            def acc(gsum, xs):
                b, w = xs
                (l, c), g = grad_fn(params, b, w)
                gsum = jax.tree.map(
                    lambda a, x: a + x.astype(accum_dtype), gsum, g)
                return gsum, (l, c)

            gsum0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)
            gsum, (ls, cs) = jax.lax.scan(acc, gsum0, (mb, w_mb))
            grads = jax.tree.map(lambda g: g / M, gsum)
            loss, ce = jnp.mean(ls), jnp.mean(cs)
        grads = fli.add_channel_noise(grads, noise_key, noise_std)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = jax.tree.map(
            lambda p, u: p + u.astype(p.dtype), params, updates)
        return params, opt_state, {"loss": loss, "ce": ce}

    p_shapes = param_shapes(cfg)
    p_specs = sr.param_specs(p_shapes, mesh)
    b_shapes = train_batch_specs(cfg, shape)
    b_specs = sr.batch_specs(b_shapes, mesh)
    opt_shapes = jax.eval_shape(optimizer.init, p_shapes)
    opt_specs = jax.tree.map(lambda _: P(), opt_shapes)
    key_shape = jax.ShapeDtypeStruct((2,), jnp.uint32)

    args = (p_shapes, opt_shapes, b_shapes, key_shape)
    shardings = (p_specs, opt_specs, b_specs, P())
    return step, args, shardings


def make_prefill_step(cfg: ArchConfig, shape: InputShape, mesh):
    def step(params, batch):
        return tfm.prefill(params, batch, cfg)

    p_shapes = param_shapes(cfg)
    p_specs = sr.param_specs(p_shapes, mesh)
    b_shapes = prefill_batch_specs(cfg, shape)
    b_specs = sr.batch_specs(b_shapes, mesh)
    args = (p_shapes, b_shapes)
    shardings = (p_specs, b_specs)

    # explicit cache out-sharding (batch over data, head_dim over model);
    # trace under the mesh context (act_spec constraints need one)
    with mesh:
        out_shapes = jax.eval_shape(step, *args)
    out_specs = (P(), sr.cache_specs(out_shapes[1], mesh))
    return step, args, shardings, out_specs


def make_decode_step(cfg: ArchConfig, shape: InputShape, mesh,
                     window_override: Optional[int] = None,
                     replicate_cache_heads: bool = False):
    """One-token serve step against a ``shape.seq_len`` cache.

    ``window_override``: serving-time sliding window (long_500k variants for
    full-attention archs — DESIGN.md §6).
    ``replicate_cache_heads``: §Perf 'cacherep' — keep the KV cache
    replicated over the model axis (q heads stay model-sharded), making the
    per-block q·k contraction device-local instead of an all-reduce over the
    sharded head_dim. Correct call when the per-device cache fits HBM
    (small-KV GQA archs)."""
    run_cfg = cfg
    if window_override:
        pattern = tuple(
            s.__class__(mixer=s.mixer,
                        window=(min(s.window, window_override) or
                                window_override) if s.mixer == "attn" else 0,
                        ffn=s.ffn)
            for s in cfg.pattern)
        run_cfg = cfg.replace(pattern=pattern)

    B = shape.global_batch
    cache_shapes = tfm.decode_cache_specs(run_cfg, B, shape.seq_len)
    token_shape = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos_shape = jax.ShapeDtypeStruct((), jnp.int32)

    enc_kv_shape = None
    if cfg.frontend == "audio_stub":
        enc_kv_shape = {
            "k": jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq, cfg.num_kv_heads, cfg.hd), cfg.cdtype),
            "v": jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq, cfg.num_kv_heads, cfg.hd), cfg.cdtype),
        }

    def step(params, token, caches, pos, enc_kv=None):
        return tfm.decode_step(params, token, caches, pos, run_cfg,
                               enc_kv=enc_kv)

    p_shapes = param_shapes(run_cfg)
    p_specs = sr.param_specs(p_shapes, mesh)
    c_specs = sr.cache_specs(cache_shapes, mesh)
    if replicate_cache_heads:
        c_specs = jax.tree.map(
            lambda s: P(*[None if p == "model" else p for p in s]),
            c_specs, is_leaf=lambda x: isinstance(x, P))
    tok_spec = sr.batch_specs(token_shape, mesh)

    if enc_kv_shape is not None:
        enc_specs = jax.tree.map(
            lambda s: sr.fit_spec(s.shape, (sr.BATCH, None, None, "model"),
                                  mesh), enc_kv_shape)
        args = (p_shapes, token_shape, cache_shapes, pos_shape, enc_kv_shape)
        shardings = (p_specs, tok_spec, c_specs, P(), enc_specs)
    else:
        args = (p_shapes, token_shape, cache_shapes, pos_shape)
        shardings = (p_specs, tok_spec, c_specs, P())
    return step, args, shardings


# ---------------------------------------------------------------------------
# Replica mode: Algorithm 1 verbatim across the data axis.
# ---------------------------------------------------------------------------

def replica_param_specs(p_shapes, mesh):
    """Per-client stacked params: client axis over data, TP over model only
    (no FSDP — clients own divergent replicas)."""
    def drop_fsdp(spec):
        parts = tuple(None if p in ("data", "pod", ("pod", "data")) else p
                      for p in spec)
        return P("data", *parts)
    base = sr.param_specs(p_shapes, mesh)
    return jax.tree.map(drop_fsdp, base,
                        is_leaf=lambda x: isinstance(x, P))


def make_replica_train_step(cfg: ArchConfig, shape: InputShape, mesh,
                            plan: fli.FLPlan, lr: float = 1e-3,
                            local_steps: int = 1):
    """Paper-faithful round: E local SGD steps per client (vmapped over the
    stacked client axis) followed by Algorithm-1 CWFL aggregation."""
    K = plan.num_clients
    B = shape.global_batch
    per_client = max(B // K, 1)

    def client_loss(params_k, batch_k):
        logits, aux = tfm.forward(params_k, batch_k, cfg)
        if cfg.frontend == "vision_stub":
            logits = logits[:, cfg.prefix_tokens:]
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        gold = jnp.take_along_axis(
            logits.astype(jnp.float32), batch_k["labels"][..., None],
            axis=-1)[..., 0]
        return jnp.mean(lse - gold) + cfg.router_aux_weight * aux

    def local_update(params_k, batch_k):
        def one(params_k, _):
            loss, grads = jax.value_and_grad(client_loss)(params_k, batch_k)
            params_k = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype),
                                    params_k, grads)
            return params_k, loss
        params_k, losses = jax.lax.scan(one, params_k, None,
                                        length=local_steps)
        return params_k, jnp.mean(losses)

    def step(stacked_params, batch, key):
        # batch leaves: (K, per_client, ...)
        stacked_params, losses = jax.vmap(local_update)(stacked_params, batch)
        # flat=True: the whole Algorithm-1 round runs flatten-once through
        # the fused single-pass kernel (repro.kernels.cwfl_round) instead
        # of the per-leaf _mix_rows loop — one HBM read of the stacked
        # params and one write of the new/consensus state per sync.
        stacked_params, consensus = cwfl_core.aggregate(
            stacked_params, plan.state, key, flat=True)
        return stacked_params, jnp.mean(losses)

    p_shapes = param_shapes(cfg)
    stacked_shapes = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((K,) + s.shape, s.dtype), p_shapes)
    p_specs = replica_param_specs(p_shapes, mesh)

    b_shapes = train_batch_specs(
        cfg, shape.__class__(shape.name, shape.seq_len, per_client * K,
                             shape.kind))
    b_shapes = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((K, per_client) + s.shape[1:], s.dtype),
        b_shapes)
    b_specs = jax.tree.map(
        lambda s: sr.fit_spec(s.shape, ("data",) + (None,) * (s.ndim - 1),
                              mesh), b_shapes)
    key_shape = jax.ShapeDtypeStruct((2,), jnp.uint32)
    args = (stacked_shapes, b_shapes, key_shape)
    shardings = (p_specs, b_specs, P())
    return step, args, shardings
