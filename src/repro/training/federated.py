"""The paper-protocol front door (`run_federated` + `FLConfig`).

One round = E local epochs at every client in parallel (vmap) followed by one
synchronization (t ∈ H) under the selected aggregation strategy.  The round
loop itself lives in :mod:`repro.sim.engine` (a `lax.scan` over rounds,
vmap-able over seeds/scenario scalars); `run_federated` is the stable
paper-protocol entry point wrapping it.

Strategies are first-class objects now: ``FLConfig.strategy`` names an
entry in the :mod:`repro.strategies` registry (``get_strategy`` /
``register_strategy``).  The old ``STRATEGIES`` mapping of bare
``(setup, aggregate)`` tuples remains as a deprecated read-only view for
one release — see the README migration note.
"""
from __future__ import annotations

import dataclasses
import warnings
from collections.abc import Mapping
from typing import Any, Callable, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.topology import Topology
from repro.strategies import available_strategies, get_strategy


class _DeprecatedStrategies(Mapping):
    """Read-only ``name -> (setup, aggregate)`` view of the strategy
    registry, kept for one release so pre-Strategy-API callers keep
    working.  Every *access* (not the import) warns — new code should
    resolve `repro.strategies.get_strategy` and call the `Strategy`
    object directly."""

    @staticmethod
    def _warn():
        warnings.warn(
            "repro.training.STRATEGIES is deprecated; use "
            "repro.strategies.get_strategy(name) and the Strategy object "
            "(init/aggregate) instead", DeprecationWarning, stacklevel=3)

    def __getitem__(self, name):
        self._warn()
        strategy = get_strategy(name)

        def setup(topology, key, *, num_clusters=3, snr_db=None, **_):
            cfg = FLConfig(strategy=strategy.name, num_clusters=num_clusters)
            return strategy.init(topology, key, cfg, snr_db=snr_db)

        def aggregate(params, state, key):
            return strategy.aggregate(params, state, key)

        return setup, aggregate

    def __iter__(self):
        self._warn()
        return iter(available_strategies())

    def __len__(self):
        self._warn()
        return len(available_strategies())


STRATEGIES = _DeprecatedStrategies()


@dataclasses.dataclass(frozen=True)
class FLConfig:
    strategy: str = "cwfl"           # resolved via repro.strategies registry
    rounds: int = 70                 # paper: 70-80 communication rounds
    local_epochs: int = 1            # E
    batch_size: int = 64             # paper: 64 (MNIST) / 32 (CIFAR)
    lr: float = 1e-3                 # paper: 0.001
    num_clusters: int = 3            # paper: 3 optimal
    snr_db: Optional[float] = 40.0   # paper: overall SNR 40 dB
    mu_prox: float = 0.0             # FedProx µ_p override (0 = use the
                                     # strategy's default, e.g. cwfl_prox)
    eval_samples: int = 2048
    seed: int = 0


def run_federated(init_fn: Callable, apply_fn: Callable, loss_fn: Callable,
                  topology: Topology, xs: jnp.ndarray, ys: jnp.ndarray,
                  x_test: jnp.ndarray, y_test: jnp.ndarray,
                  cfg: FLConfig, progress: Optional[Callable] = None,
                  scenario=None, topo_cfg=None) -> dict[str, Any]:
    """Run FL; returns history dict with per-round test accuracy/loss.

    ``xs, ys``: stacked client shards (K, N_k, ...).

    Compatibility wrapper over the scenario engine
    (:func:`repro.sim.engine.run_rounds`).  With the default (static)
    scenario the scanned engine's history is bit-identical to the legacy
    per-round Python loop this function used to implement; when a live
    ``progress`` callback is given the engine's loop mode (same numbers,
    per-round host sync) is used so the callback fires as rounds finish.
    ``scenario``/``topo_cfg`` opt into `repro.sim` dynamics (time-varying
    channels, participation masks, re-clustering).
    """
    from repro.sim.engine import run_rounds  # deferred: sim imports us

    mode = "loop" if progress is not None else "scan"
    h = run_rounds(init_fn, apply_fn, loss_fn, topology, xs, ys,
                   x_test, y_test, cfg, scenario=scenario,
                   topo_cfg=topo_cfg, mode=mode, progress=progress)

    history = {
        "round": [int(r) for r in h["round"]],
        "train_loss": [float(x) for x in np.asarray(h["train_loss"])],
        "test_acc": [float(x) for x in np.asarray(h["test_acc"])],
    }
    history["final_params"] = h["final_params"]
    history["avg_acc"] = float(h["avg_acc"])
    history["final_acc"] = history["test_acc"][-1]
    return history
