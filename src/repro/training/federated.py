"""The federated round engine (Algorithm 1 + baselines, vmapped over clients).

One round = E local epochs at every client in parallel (vmap) followed by one
synchronization (t ∈ H) under the selected aggregation strategy.  The whole
round is a single jitted function; clients are the leading axis of every
parameter leaf.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import baselines, cwfl
from repro.core.topology import Topology
from repro.models.small import accuracy as _accuracy
from repro.optim import sgd
from repro.training.local import make_local_runner


# ---------------------------------------------------------------------------
# Strategy registry: name -> (setup, aggregate).
# ---------------------------------------------------------------------------

def _cwfl_setup(topology, key, *, num_clusters=3, snr_db=None, **_):
    return cwfl.setup(topology, cwfl.CWFLConfig(num_clusters=num_clusters,
                                                snr_db=snr_db), key)


def _cwfl_aggregate(params, state, key):
    return cwfl.aggregate(params, state, key)


def _cotaf_setup(topology, key, *, snr_db=None, **_):
    return baselines.cotaf_setup(topology, key, snr_db=snr_db)


def _fedavg_setup(topology, key, **_):
    del topology, key
    return None


def _fedavg_aggregate(params, state, key):
    del state, key
    return baselines.fedavg_aggregate(params)


def _dec_setup(topology, key, *, snr_db=None, **_):
    return baselines.decentralized_setup(topology, key, snr_db=snr_db)


STRATEGIES = {
    "cwfl": (_cwfl_setup, _cwfl_aggregate),
    "cotaf": (_cotaf_setup, baselines.cotaf_aggregate),
    "fedavg": (_fedavg_setup, _fedavg_aggregate),
    "decentralized": (_dec_setup, baselines.decentralized_aggregate),
}


@dataclasses.dataclass(frozen=True)
class FLConfig:
    strategy: str = "cwfl"
    rounds: int = 70                 # paper: 70-80 communication rounds
    local_epochs: int = 1            # E
    batch_size: int = 64             # paper: 64 (MNIST) / 32 (CIFAR)
    lr: float = 1e-3                 # paper: 0.001
    num_clusters: int = 3            # paper: 3 optimal
    snr_db: Optional[float] = 40.0   # paper: overall SNR 40 dB
    mu_prox: float = 0.0             # FedProx µ_p (0 = off)
    eval_samples: int = 2048
    seed: int = 0


def run_federated(init_fn: Callable, apply_fn: Callable, loss_fn: Callable,
                  topology: Topology, xs: jnp.ndarray, ys: jnp.ndarray,
                  x_test: jnp.ndarray, y_test: jnp.ndarray,
                  cfg: FLConfig, progress: Optional[Callable] = None
                  ) -> dict[str, Any]:
    """Run FL; returns history dict with per-round test accuracy/loss.

    ``xs, ys``: stacked client shards (K, N_k, ...).
    """
    if cfg.strategy not in STRATEGIES:
        raise KeyError(f"unknown strategy {cfg.strategy!r}; "
                       f"choose from {sorted(STRATEGIES)}")
    setup_fn, aggregate_fn = STRATEGIES[cfg.strategy]

    K, n_k = xs.shape[0], xs.shape[1]
    key = jax.random.PRNGKey(cfg.seed)
    k_state, k_init, k_rounds = jax.random.split(key, 3)

    state = setup_fn(topology, k_state, num_clusters=cfg.num_clusters,
                     snr_db=cfg.snr_db)

    # Same initialization at all clients (Algorithm 1: "Initialize parameters
    # at all clients").
    params0 = init_fn(k_init)
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (K,) + x.shape), params0)

    optimizer = sgd(cfg.lr)
    steps_per_round = max(cfg.local_epochs * (n_k // cfg.batch_size), 1)
    local_run = make_local_runner(loss_fn, optimizer, cfg.batch_size,
                                  steps_per_round, cfg.mu_prox)
    opt_state = jax.vmap(optimizer.init)(stacked)

    x_ev = x_test[: cfg.eval_samples]
    y_ev = y_test[: cfg.eval_samples]

    @jax.jit
    def round_fn(stacked, opt_state, key):
        k_local, k_agg = jax.random.split(key)
        client_keys = jax.random.split(k_local, K)
        stacked, opt_state, losses = jax.vmap(local_run)(
            stacked, opt_state, xs, ys, client_keys)
        stacked, consensus = aggregate_fn(stacked, state, k_agg)
        logits = apply_fn(consensus, x_ev)
        acc = _accuracy(logits, y_ev)
        return stacked, opt_state, jnp.mean(losses), acc, consensus

    history = {"round": [], "train_loss": [], "test_acc": []}
    consensus = params0
    round_keys = jax.random.split(k_rounds, cfg.rounds)
    for r in range(cfg.rounds):
        stacked, opt_state, loss, acc, consensus = round_fn(
            stacked, opt_state, round_keys[r])
        history["round"].append(r + 1)
        history["train_loss"].append(float(loss))
        history["test_acc"].append(float(acc))
        if progress is not None:
            progress(r + 1, float(loss), float(acc))

    history["final_params"] = consensus
    history["avg_acc"] = float(jnp.mean(jnp.asarray(history["test_acc"])))
    history["final_acc"] = history["test_acc"][-1]
    return history
