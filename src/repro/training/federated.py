"""The federated strategy registry + compatibility front door.

One round = E local epochs at every client in parallel (vmap) followed by one
synchronization (t ∈ H) under the selected aggregation strategy.  The round
loop itself lives in :mod:`repro.sim.engine` (a `lax.scan` over rounds,
vmap-able over seeds/scenario scalars); `run_federated` is the stable
paper-protocol entry point wrapping it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import baselines, cwfl
from repro.core.topology import Topology


# ---------------------------------------------------------------------------
# Strategy registry: name -> (setup, aggregate).
# ---------------------------------------------------------------------------

def _cwfl_setup(topology, key, *, num_clusters=3, snr_db=None, **_):
    return cwfl.setup(topology, cwfl.CWFLConfig(num_clusters=num_clusters,
                                                snr_db=snr_db), key)


def _cwfl_aggregate(params, state, key):
    return cwfl.aggregate(params, state, key)


def _cotaf_setup(topology, key, *, snr_db=None, **_):
    return baselines.cotaf_setup(topology, key, snr_db=snr_db)


def _fedavg_setup(topology, key, **_):
    del topology, key
    return None


def _fedavg_aggregate(params, state, key):
    del state, key
    return baselines.fedavg_aggregate(params)


def _dec_setup(topology, key, *, snr_db=None, **_):
    return baselines.decentralized_setup(topology, key, snr_db=snr_db)


STRATEGIES = {
    "cwfl": (_cwfl_setup, _cwfl_aggregate),
    "cotaf": (_cotaf_setup, baselines.cotaf_aggregate),
    "fedavg": (_fedavg_setup, _fedavg_aggregate),
    "decentralized": (_dec_setup, baselines.decentralized_aggregate),
}


@dataclasses.dataclass(frozen=True)
class FLConfig:
    strategy: str = "cwfl"
    rounds: int = 70                 # paper: 70-80 communication rounds
    local_epochs: int = 1            # E
    batch_size: int = 64             # paper: 64 (MNIST) / 32 (CIFAR)
    lr: float = 1e-3                 # paper: 0.001
    num_clusters: int = 3            # paper: 3 optimal
    snr_db: Optional[float] = 40.0   # paper: overall SNR 40 dB
    mu_prox: float = 0.0             # FedProx µ_p (0 = off)
    eval_samples: int = 2048
    seed: int = 0


def run_federated(init_fn: Callable, apply_fn: Callable, loss_fn: Callable,
                  topology: Topology, xs: jnp.ndarray, ys: jnp.ndarray,
                  x_test: jnp.ndarray, y_test: jnp.ndarray,
                  cfg: FLConfig, progress: Optional[Callable] = None,
                  scenario=None, topo_cfg=None) -> dict[str, Any]:
    """Run FL; returns history dict with per-round test accuracy/loss.

    ``xs, ys``: stacked client shards (K, N_k, ...).

    Compatibility wrapper over the scenario engine
    (:func:`repro.sim.engine.run_rounds`).  With the default (static)
    scenario the scanned engine's history is bit-identical to the legacy
    per-round Python loop this function used to implement; when a live
    ``progress`` callback is given the engine's loop mode (same numbers,
    per-round host sync) is used so the callback fires as rounds finish.
    ``scenario``/``topo_cfg`` opt into `repro.sim` dynamics (time-varying
    channels, participation masks, re-clustering).
    """
    from repro.sim.engine import run_rounds  # deferred: sim imports us

    mode = "loop" if progress is not None else "scan"
    h = run_rounds(init_fn, apply_fn, loss_fn, topology, xs, ys,
                   x_test, y_test, cfg, scenario=scenario,
                   topo_cfg=topo_cfg, mode=mode, progress=progress)

    history = {
        "round": [int(r) for r in h["round"]],
        "train_loss": [float(x) for x in np.asarray(h["train_loss"])],
        "test_acc": [float(x) for x in np.asarray(h["test_acc"])],
    }
    history["final_params"] = h["final_params"]
    history["avg_acc"] = float(h["avg_acc"])
    history["final_acc"] = history["test_acc"][-1]
    return history
