"""Single-model train/serve step builders (the distributed versions wrap
these with shardings + the CWFL gradient collective; see repro.dist).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.models.config import ArchConfig


def cross_entropy(logits, labels):
    """Mean token CE in float32. logits: (B, S, V), labels: (B, S) int32."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def make_loss_fn(cfg: ArchConfig) -> Callable:
    def loss_fn(params, batch):
        logits, aux = tfm.forward(params, batch, cfg)
        if cfg.frontend == "vision_stub":
            logits = logits[:, cfg.prefix_tokens:]
        ce = cross_entropy(logits, batch["labels"])
        return ce + cfg.router_aux_weight * aux, ce
    return loss_fn


def make_train_step(cfg: ArchConfig, optimizer) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""
    loss_fn = make_loss_fn(cfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(params, opt_state, batch):
        (loss, ce), grads = grad_fn(params, batch)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = jax.tree.map(jnp.add, params, updates)
        return params, opt_state, {"loss": loss, "ce": ce}

    return step


def make_prefill_step(cfg: ArchConfig) -> Callable:
    def step(params, batch):
        return tfm.prefill(params, batch, cfg)
    return step


def make_decode_step(cfg: ArchConfig) -> Callable:
    """(params, token (B,1), caches, pos) -> (logits (B,1,V), caches)."""
    def step(params, token, caches, pos, enc_kv=None):
        return tfm.decode_step(params, token, caches, pos, cfg, enc_kv=enc_kv)
    return step
