"""Quickstart: CWFL end-to-end on a synthetic MNIST-like task (CPU, ~2 min).

Builds a 16-client wireless topology, clusters it by link SNR (paper §IV),
runs 12 federated rounds of CWFL vs the ideal FedAvg server, and prints the
accuracy trajectory plus the channel-use saving vs decentralized FL.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import TopologyConfig, make_topology, clustering
from repro.core.cwfl import channel_uses_per_round
from repro.data import SyntheticImageConfig, make_synthetic_images, partition_iid
from repro.models import make_mnist_mlp, nll_loss
from repro.training import FLConfig, run_federated


def main():
    key = jax.random.PRNGKey(0)
    K, clusters = 16, 3

    print("== topology & SNR clustering (offline phase) ==")
    topo = make_topology(key, TopologyConfig(num_clients=K, num_hotspots=3))
    plan = clustering.make_cluster_plan(topo.link_snr, topo.adjacency,
                                        clusters, key)
    print(f"clients: {K}, clusters: {plan.assignment.tolist()}")
    print(f"cluster heads: {plan.heads.tolist()}")
    print(f"cluster SNRs (dB): "
          f"{[round(float(10*jax.numpy.log10(x)), 1) for x in plan.cluster_snr]}")
    uses = channel_uses_per_round(K, clusters)
    print(f"channel uses/round: CWFL={uses['cwfl']} vs "
          f"decentralized={uses['decentralized']} "
          f"({uses['decentralized']/uses['cwfl']:.0f}x saving)\n")

    print("== data (synthetic MNIST-like, IID split) ==")
    dcfg = SyntheticImageConfig.mnist_like(num_train=6000, num_test=1500)
    (xtr, ytr), (xte, yte) = make_synthetic_images(jax.random.PRNGKey(1), dcfg)
    xs, ys = partition_iid(jax.random.PRNGKey(2), xtr, ytr, K)
    init, apply = make_mnist_mlp()
    loss = lambda p, x, y: nll_loss(apply(p, x), y)

    for strategy in ("cwfl", "fedavg"):
        print(f"== {strategy} ==")
        h = run_federated(
            init, apply, loss, topo, xs, ys, xte, yte,
            FLConfig(strategy=strategy, rounds=12, num_clusters=clusters,
                     snr_db=40.0, eval_samples=1024),
            progress=lambda r, l, a: print(
                f"  round {r:2d}  loss={l:.3f}  acc={a:.3f}"))
        print(f"  final accuracy: {h['final_acc']:.3f}\n")


if __name__ == "__main__":
    main()
