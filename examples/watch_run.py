"""Terminal watcher for a live `repro.obs.stream` JSONL.

    PYTHONPATH=src python examples/run_scenario.py --stream live.jsonl \
        --alerts &
    PYTHONPATH=src python examples/watch_run.py live.jsonl --follow

Tails the JSONL a streamed run (``run_scenario.py --stream``) appends to
while its scan executes and renders, per trajectory: loss/accuracy
sparklines, per-cluster loss, participation, the cumulative OTA
channel-use ledger, and any active `repro.obs.monitor` alerts.  Also
reads post-hoc telemetry files (``--telemetry`` / ``write_history``
"round" records) — the live and post-hoc planes share field names by
construction, so one renderer covers both.

The default (``--once``) renders the current file state and exits;
``--follow`` re-renders as the file grows (ANSI clear, 1 Hz).  ``--fail-on-alert`` exits 2 if any alert record is present —
the CI chaos gate.  Stdlib only: safe to point at a file another
process holds open.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

BLOCKS = "▁▂▃▄▅▆▇█"


def sparkline(vals, width: int = 24) -> str:
    """Min-max normalized block sparkline of the last ``width`` values
    (non-finite values render as spaces)."""
    vals = list(vals)[-width:]
    finite = [v for v in vals if v is not None and v == v
              and abs(v) != float("inf")]
    if not finite:
        return " " * len(vals)
    lo, hi = min(finite), max(finite)
    span = (hi - lo) or 1.0
    out = []
    for v in vals:
        if v is None or v != v or abs(v) == float("inf"):
            out.append(" ")
        else:
            out.append(BLOCKS[int((len(BLOCKS) - 1) * (v - lo) / span)])
    return "".join(out)


def _traj_key(rec: dict) -> tuple:
    return (rec.get("seed"), rec.get("snr_db"))


class RunView:
    """Incremental parse state of one stream/telemetry JSONL."""

    def __init__(self):
        self.manifest = None
        self.trajs: dict = {}        # (seed, snr_db) -> [round records]
        self.alerts: list = []
        self.offset = 0              # bytes consumed so far
        self.bad_lines = 0

    def feed(self, path: str) -> int:
        """Consume newly appended complete lines; returns #new records."""
        new = 0
        try:
            size = os.path.getsize(path)
        except OSError:
            return 0
        if size < self.offset:       # truncated/rewritten: start over
            self.__init__()
        with open(path, "r") as f:
            f.seek(self.offset)
            for line in f:
                if not line.endswith("\n"):
                    break            # partial line mid-append; retry later
                self.offset += len(line.encode("utf-8"))
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except ValueError:
                    self.bad_lines += 1
                    continue
                self._ingest(rec)
                new += 1
        return new

    def _ingest(self, rec: dict) -> None:
        kind = rec.get("type")
        if kind == "manifest":
            self.manifest = rec
        elif kind in ("stream", "round"):
            self.trajs.setdefault(_traj_key(rec), []).append(rec)
        elif kind == "alert":
            self.alerts.append(rec)
        # summary/monitor/unknown records: nothing to draw

    def render(self) -> str:
        lines = []
        if self.manifest is not None:
            m = self.manifest
            cfg = m.get("config", {}) if isinstance(m.get("config"), dict) \
                else {}
            bits = [str(m.get("scenario", m.get("name", "run"))),
                    str(cfg.get("strategy", m.get("strategy", "")))]
            head = " / ".join(b for b in bits if b)
            if cfg.get("rounds"):
                head += f"  rounds={cfg['rounds']}"
            lines.append(f"watch: {head}")
        total = sum(len(v) for v in self.trajs.values())
        lines.append(f"{len(self.trajs)} trajectories, {total} round "
                     f"records, {len(self.alerts)} alerts")
        for key in sorted(self.trajs,
                          key=lambda k: (k[0] or 0, k[1] or 0.0)):
            recs = sorted(self.trajs[key], key=lambda r: r.get("round", 0))
            last = recs[-1]
            seed, snr = key
            tag = "trajectory"
            if seed is not None:
                tag += f" seed={seed}"
            if snr is not None:
                tag += f" snr={snr:g}dB"
            loss = [r.get("train_loss") for r in recs]
            acc = [r.get("test_acc") for r in recs]
            lines.append(f"{tag}  round {last.get('round', '?')}")
            lines.append(f"  loss {sparkline(loss)} {loss[-1]:.4f}   "
                         f"acc {sparkline(acc)} {acc[-1]:.3f}")
            tele = last.get("telemetry") or {}
            cl = tele.get("cluster_loss")
            if cl:
                per = " ".join(f"c{i}={v:.3f}" for i, v in enumerate(cl))
                lines.append(f"  cluster loss: {per}")
            if tele:
                lines.append(
                    f"  participants={_as_int(tele.get('participants'))}"
                    f"  uses/round={_as_int(tele.get('channel_uses'))}"
                    f"  cum_uses={_as_int(tele.get('cum_channel_uses'))}"
                    f"  cum_symbols={_as_int(tele.get('cum_symbols'))}")
        if self.alerts:
            lines.append("ALERTS:")
            for a in self.alerts[-8:]:
                traj = a.get("trajectory") or {}
                where = "" if traj.get("seed") is None \
                    else f" seed={traj['seed']}"
                lines.append(f"  [{a.get('rule')}] round "
                             f"{a.get('round')}{where}: "
                             f"{a.get('message', '')}")
        if self.bad_lines:
            lines.append(f"({self.bad_lines} unparseable lines skipped)")
        return "\n".join(lines)


def _as_int(v):
    try:
        return int(v)
    except (TypeError, ValueError):
        return "?"


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path", help="stream/telemetry JSONL to watch")
    ap.add_argument("--follow", action="store_true",
                    help="keep tailing and re-rendering as the file grows")
    ap.add_argument("--once", action="store_true",
                    help="render current state and exit (the default; "
                         "overrides --follow)")
    ap.add_argument("--interval", type=float, default=1.0,
                    help="--follow poll interval in seconds")
    ap.add_argument("--timeout", type=float, default=None,
                    help="--follow: stop after this many seconds without "
                         "new records (default: run until interrupted)")
    ap.add_argument("--fail-on-alert", action="store_true",
                    help="exit 2 if any alert record is present (CI gate)")
    args = ap.parse_args()
    follow = args.follow and not args.once

    view = RunView()
    view.feed(args.path)
    if follow:
        quiet = 0.0
        try:
            while True:
                sys.stdout.write("\x1b[2J\x1b[H" + view.render() + "\n")
                sys.stdout.flush()
                time.sleep(args.interval)
                quiet = 0.0 if view.feed(args.path) else \
                    quiet + args.interval
                if args.timeout is not None and quiet >= args.timeout:
                    break
        except KeyboardInterrupt:
            pass
    print(view.render())
    if args.fail_on_alert and view.alerts:
        return 2
    return 0


if __name__ == "__main__":
    sys.exit(main())
