"""End-to-end driver: train a transformer LM with CWFL gradient aggregation.

This is the shard-mode integration (DESIGN.md §3): clients are data-parallel
groups; the CWFL consensus enters as per-example loss weights + channel
noise. Data is a synthetic Markov token stream (offline container).

Default: a ~6M-parameter model, 300 steps, CPU-friendly (~5 min).
``--large`` trains a ~100M-parameter model (slow on 1 CPU — use fewer steps).

    PYTHONPATH=src python examples/train_lm_cwfl.py [--steps 300] [--large]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.dist.fl_integration import make_fl_plan
from repro.launch.mesh import make_local_mesh
from repro.models.config import ArchConfig, InputShape, LayerSpec
from repro.data import make_token_dataset
from repro.training import dist_steps as ds
from repro.checkpoint import save_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--large", action="store_true")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--lr", type=float, default=0.3)
    ap.add_argument("--snr-db", type=float, default=40.0)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    if args.large:   # ~100M params
        cfg = ArchConfig(name="lm-100m", arch_type="dense", num_layers=12,
                         d_model=768, num_heads=12, num_kv_heads=4,
                         d_ff=2048, vocab_size=32768,
                         pattern=(LayerSpec(),), tie_embeddings=True)
    else:            # ~6M params
        cfg = ArchConfig(name="lm-6m", arch_type="dense", num_layers=4,
                         d_model=256, num_heads=4, num_kv_heads=2,
                         d_ff=768, vocab_size=4096,
                         pattern=(LayerSpec(),), tie_embeddings=True)

    from repro.models.transformer import count_params, init_params
    print(f"model: {cfg.name}  params={count_params(cfg)/1e6:.1f}M")

    mesh = make_local_mesh(1, 1)
    shape = InputShape("train", args.seq, args.batch, "train")
    plan = make_fl_plan(args.clients, min(3, args.clients),
                        jax.random.PRNGKey(0), snr_db=args.snr_db)
    print(f"CWFL plan: {args.clients} clients, clusters="
          f"{plan.assignment.tolist()}, channel-noise std={plan.noise_std:.2e}")

    step_fn, _, _ = ds.make_train_step(cfg, shape, mesh, plan=plan,
                                       lr=args.lr, microbatches=1)
    step_fn = jax.jit(step_fn)

    data = make_token_dataset(jax.random.PRNGKey(1), cfg.vocab_size,
                              num_sequences=4096, seq_len=args.seq)
    params = init_params(jax.random.PRNGKey(2), cfg)
    from repro.optim import sgd
    opt_state = sgd(args.lr).init(params)

    key = jax.random.PRNGKey(3)
    t0 = time.time()
    for step in range(args.steps):
        k_it, k_noise, key = jax.random.split(key, 3)
        idx = jax.random.randint(k_it, (args.batch,), 0, data.shape[0])
        seqs = data[idx]
        batch = {"tokens": seqs[:, :-1], "labels": seqs[:, 1:]}
        params, opt_state, metrics = step_fn(params, opt_state, batch,
                                             k_noise)
        if step % 25 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  ce={float(metrics['ce']):.4f}  "
                  f"({(time.time()-t0):.0f}s)")
    uniform = float(jnp.log(cfg.vocab_size))
    print(f"final ce {float(metrics['ce']):.3f} vs uniform {uniform:.3f}")
    if args.ckpt:
        path = save_checkpoint(args.ckpt, args.steps, params)
        print("checkpoint:", path)


if __name__ == "__main__":
    main()
