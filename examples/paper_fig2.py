"""Reproduce the paper's Figure 2 / Table I experiment grid (scaled).

    PYTHONPATH=src python examples/paper_fig2.py          # ~20 min scaled grid
    PYTHONPATH=src python examples/paper_fig2.py --fast   # 4 curves, ~4 min
    PYTHONPATH=src python examples/paper_fig2.py --full   # paper-scale (hours)
"""
import argparse

from benchmarks.common import BenchScale
from benchmarks import fig2_accuracy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    scale = BenchScale.full() if args.full else BenchScale()
    rows = fig2_accuracy.run(scale, subset=4 if args.fast else None)
    print("\nsummary (final accuracy):")
    for r in rows:
        print(f"  {r['dataset']:6s} {'iid' if r['iid'] else 'noniid':6s} "
              f"{r['label']:14s} {r['final_acc']:.3f}")


if __name__ == "__main__":
    main()
