"""Render a `repro.obs` telemetry JSONL run into a markdown report.

    PYTHONPATH=src python examples/run_scenario.py --telemetry run.jsonl
    PYTHONPATH=src python examples/obs_report.py run.jsonl [--out REPORT.md]

Sections: run provenance (the `repro.obs.manifest` record), per-cluster
convergence (mean cluster loss + consensus drift ‖θ_c − θ̄‖ per round),
the OTA communication-cost ledger (channel uses / scalar symbols, with
the paper's §IV CWFL-vs-decentralized savings row), participation and
injected-noise telemetry, and the phase wall timings
(trace+compile / execute / gather).  Monte-Carlo runs are averaged
across trajectories (the per-round tables report trajectory means).
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

MANIFEST_FIELDS = ("strategy", "scenario", "config_hash", "git",
                   "jax_version", "backend", "device_kind", "device_count",
                   "hostname", "created")


def _fmt(v) -> str:
    if isinstance(v, dict):          # git record
        sha = v.get("sha", "")[:12]
        return f"{sha}{' (dirty)' if v.get('dirty') else ''}" or str(v)
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def _by_round(rounds: list[dict]) -> dict[int, list[dict]]:
    """Group round records by round number (MC runs have one record per
    trajectory per round)."""
    out: dict[int, list[dict]] = {}
    for r in rounds:
        out.setdefault(int(r["round"]), []).append(r)
    return dict(sorted(out.items()))


def _mean(records: list[dict], *keys):
    """Trajectory-mean of a (possibly nested) telemetry field; None if the
    field is absent."""
    vals = []
    for r in records:
        v = r
        for k in keys:
            v = v.get(k) if isinstance(v, dict) else None
            if v is None:
                return None
        vals.append(np.asarray(v, dtype=np.float64))
    return np.mean(np.stack(vals), axis=0)


def manifest_section(man: dict | None) -> list[str]:
    out = ["## Run"]
    if man is None:
        return out + ["", "_no manifest record in stream_"]
    out += ["", "| field | value |", "|---|---|"]
    for k in MANIFEST_FIELDS:
        if k in man:
            out.append(f"| {k} | {_fmt(man[k])} |")
    return out


def convergence_section(per_round: dict[int, list[dict]]) -> list[str]:
    sample = _mean(next(iter(per_round.values())),
                   "telemetry", "cluster_loss")
    if sample is None:
        return []
    C = sample.shape[0]
    hdr = "| round | train loss | test acc | " + " | ".join(
        f"loss c{c}" for c in range(C)) + " | " + " | ".join(
        f"drift c{c}" for c in range(C)) + " |"
    out = ["## Per-cluster convergence", "",
           f"{C} aggregation site(s); drift = ‖θ_c − θ̄‖.", "",
           hdr, "|" + "---|" * (3 + 2 * C)]
    for t, recs in per_round.items():
        cl = _mean(recs, "telemetry", "cluster_loss")
        dr = _mean(recs, "telemetry", "consensus_drift")
        tl = _mean(recs, "train_loss")
        ta = _mean(recs, "test_acc")
        row = [f"{t}", f"{float(tl):.4f}", f"{float(ta):.4f}"]
        row += [f"{v:.4f}" for v in np.atleast_1d(cl)]
        row += [f"{v:.4f}" for v in np.atleast_1d(dr)]
        out.append("| " + " | ".join(row) + " |")
    return out


def communication_section(per_round: dict[int, list[dict]],
                          man: dict | None) -> list[str]:
    if _mean(next(iter(per_round.values())),
             "telemetry", "channel_uses") is None:
        return []
    out = ["## Communication cost (OTA channel-use ledger)", "",
           "| round | uses | cum uses | cum symbols | reclustered |",
           "|---|---|---|---|---|"]
    for t, recs in per_round.items():
        u = _mean(recs, "telemetry", "channel_uses")
        cu = _mean(recs, "telemetry", "cum_channel_uses")
        cs = _mean(recs, "telemetry", "cum_symbols")
        rc = _mean(recs, "telemetry", "reclustered")
        out.append(f"| {t} | {float(u):.0f} | {float(cu):.0f} | "
                   f"{float(cs):.3g} | {float(rc):.2f} |")
    cfg = (man or {}).get("config") or {}
    K = (man or {}).get("clients") or 0
    C = cfg.get("num_clusters")
    if K and C and int(C) < int(K):
        from repro.obs.ledger import per_round_table
        tab = per_round_table(int(K), int(C))
        out += ["",
                f"Paper §IV comparison at K={K}, C={C}: "
                f"cwfl={tab['cwfl']}, decentralized={tab['decentralized']}, "
                f"server_ota={tab['server_ota']} uses/round "
                f"(cwfl saves {tab['decentralized'] / tab['cwfl']:.1f}× "
                f"vs decentralized)."]
    return out


def participation_section(per_round: dict[int, list[dict]]) -> list[str]:
    if _mean(next(iter(per_round.values())),
             "telemetry", "participants") is None:
        return []
    noise_keys = [k for k in ("noise_energy", "mac_noise_std",
                              "receive_noise_std", "power_budget_frac")
                  if _mean(next(iter(per_round.values())),
                           "telemetry", "extras", k) is not None]
    hdr = "| round | participants |" + "".join(f" {k} |" for k in noise_keys)
    out = ["## Participation & noise", "", hdr,
           "|" + "---|" * (2 + len(noise_keys))]
    for t, recs in per_round.items():
        p = _mean(recs, "telemetry", "participants")
        row = [f"{t}", f"{float(p):.2f}"]
        for k in noise_keys:
            v = _mean(recs, "telemetry", "extras", k)
            row.append(f"{float(np.mean(v)):.4g}")
        out.append("| " + " | ".join(row) + " |")
    return out


def timings_section(summary: dict | None) -> list[str]:
    timings = (summary or {}).get("timings")
    if not timings:
        return []
    out = ["## Phase timings", "", "| phase | seconds |", "|---|---|"]
    for name, secs in sorted(timings.items()):
        out.append(f"| {name} | {secs:.3f} |")
    return out


def render(run: dict) -> str:
    man, rounds, summary = run["manifest"], run["rounds"], run["summary"]
    title = "# Observability report"
    if man:
        title += f" — {man.get('strategy')} / {man.get('scenario')}"
    blocks = [[title]]
    blocks.append(manifest_section(man))
    if rounds:
        per_round = _by_round(rounds)
        n_traj = len(next(iter(per_round.values())))
        if n_traj > 1:
            blocks.append([f"_{n_traj} trajectories; per-round tables are "
                           f"trajectory means._"])
        blocks.append(convergence_section(per_round))
        blocks.append(communication_section(per_round, man))
        blocks.append(participation_section(per_round))
    if summary:
        fin = np.atleast_1d(np.asarray(summary.get("final_acc", [])))
        line = (f"**Final acc** {fin.mean():.4f}"
                + (f" ± {fin.std():.4f} ({fin.size} trajectories)"
                   if fin.size > 1 else ""))
        if "cum_channel_uses" in summary:
            cu = np.mean(np.asarray(summary["cum_channel_uses"]))
            cs = np.mean(np.asarray(summary["cum_symbols"]))
            line += (f" · **total channel uses** {cu:.0f}"
                     f" · **total symbols** {cs:.3g}")
        blocks.append(["## Summary", "", line])
    blocks.append(timings_section(summary))
    return "\n\n".join("\n".join(b) for b in blocks if b) + "\n"


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("jsonl", help="telemetry JSONL stream "
                                  "(run_scenario.py --telemetry OUT)")
    ap.add_argument("--out", default=None,
                    help="write the markdown here instead of stdout")
    args = ap.parse_args(argv)

    from repro.obs.sink import read_run
    md = render(read_run(args.jsonl))
    if args.out:
        with open(args.out, "w") as f:
            f.write(md)
        print(f"wrote {args.out}", file=sys.stderr)
    else:
        print(md, end="")


if __name__ == "__main__":
    main()
