"""Run a named `repro.sim` scenario: dynamic channels, scheduling, Monte-Carlo.

    PYTHONPATH=src python examples/run_scenario.py --scenario mobile-fading --seeds 8
    PYTHONPATH=src python examples/run_scenario.py --scenario snr-sweep --seeds 4
    PYTHONPATH=src python examples/run_scenario.py --seeds 8 --shard mc
    PYTHONPATH=src python examples/run_scenario.py --shard clients
    PYTHONPATH=src python examples/run_scenario.py --telemetry run.jsonl
    PYTHONPATH=src python examples/run_scenario.py --scenario head-failure \
        --checkpoint-dir ckpt --checkpoint-every 4 --stop-after 4   # "crash"
    PYTHONPATH=src python examples/run_scenario.py --scenario head-failure \
        --checkpoint-dir ckpt --checkpoint-every 4 --resume         # bitwise
    PYTHONPATH=src python examples/run_scenario.py --list

One seed runs a single scanned trajectory; ``--seeds N`` (N > 1) runs the
whole N-seed (× SNR-grid, for sweep scenarios) Monte-Carlo batch as ONE
jit via `repro.sim.run_monte_carlo` and reports mean ± std across seeds.

``--shard mc`` distributes the flattened trajectory grid over the device
mesh (`repro.sim.sharded`; pair with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` on CPU);
``--shard clients`` splits the stacked K-client axis of a single
trajectory instead.  ``--devices N`` caps the mesh; ``--assert-match-vmap``
re-runs the single-device vmap sweep and asserts the sharded metrics
match it (bitwise for seeds-only sweeps; ulp-level for SNR grids — see
DESIGN.md §Sharded-MC).

``--telemetry OUT.jsonl`` turns on the in-scan `repro.obs` round
telemetry (per-cluster loss, participation, consensus drift, the OTA
channel-use ledger, strategy internals) and writes the run — manifest,
per-round records, summary with phase wall timings — as a JSONL stream
`examples/obs_report.py` renders to markdown.  ``--profile-dir DIR``
additionally captures a TensorBoard-loadable ``jax.profiler`` trace.

``--stream OUT.jsonl`` goes LIVE instead of post-hoc: the scan body
drains every round to an append-mode JSONL while the run executes
(`repro.obs.stream`) — tail it with ``examples/watch_run.py --follow``.
``--alerts`` attaches the `repro.obs.monitor` rule engine (non-finite
loss, consensus-drift blowup, quarantine rate, eq. (5) power budget,
c/T convergence stall) whose alert records ride the same stream;
``--abort-on-alert`` escalates any alert to a checkpoint-then-stop
(requires ``--checkpoint-dir``; the aborted run resumes with
``--resume``, its stream appending where it left off).  ``--prom
OUT.prom`` additionally exports latest-round gauges as a
Prometheus-style textfile.

    PYTHONPATH=src python examples/run_scenario.py --stream live.jsonl \
        --alerts &
    PYTHONPATH=src python examples/watch_run.py live.jsonl --follow
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenario", default="paper-static")
    ap.add_argument("--list", action="store_true",
                    help="list registered scenarios and exit")
    ap.add_argument("--seeds", type=int, default=1)
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--clients", type=int, default=12)
    ap.add_argument("--clusters", type=int, default=3)
    ap.add_argument("--strategy", default=None,
                    help="aggregation strategy (repro.strategies registry; "
                         "--list shows the registered names). Default: the "
                         "scenario's pinned strategy, else cwfl")
    ap.add_argument("--snr-db", type=float, default=40.0,
                    help="overall SNR (ignored by snr-sweep's grid)")
    ap.add_argument("--hidden", type=int, default=64,
                    help="MLP hidden width (tiny default for CPU)")
    ap.add_argument("--train", type=int, default=4800)
    ap.add_argument("--test", type=int, default=1024)
    ap.add_argument("--out", default=None, help="optional JSON output path")
    ap.add_argument("--shard", choices=["mc", "clients"], default=None,
                    help="mc: shard the Monte-Carlo trajectory grid over "
                         "the device mesh; clients: shard the stacked "
                         "K-client axis of one trajectory")
    ap.add_argument("--devices", type=int, default=0,
                    help="mesh size for --shard (0 = all visible devices)")
    ap.add_argument("--assert-match-vmap", action="store_true",
                    help="with --shard mc: also run the single-device vmap "
                         "sweep and assert the metrics match")
    ap.add_argument("--telemetry", default=None, metavar="OUT.jsonl",
                    help="record in-scan round telemetry (repro.obs) and "
                         "write the run as a JSONL stream — manifest, one "
                         "record per (trajectory, round), summary with "
                         "phase timings; render with examples/obs_report.py")
    ap.add_argument("--stream", default=None, metavar="OUT.jsonl",
                    help="LIVE telemetry: drain every round to this JSONL "
                         "while the scan executes (repro.obs.stream); tail "
                         "with examples/watch_run.py --follow. Implies the "
                         "in-scan telemetry plane")
    ap.add_argument("--alerts", action="store_true",
                    help="attach the repro.obs.monitor rule engine to the "
                         "stream; alert records ride the same JSONL "
                         "(requires --stream)")
    ap.add_argument("--abort-on-alert", action="store_true",
                    help="escalate any alert to checkpoint-then-stop "
                         "(requires --stream and --checkpoint-dir; resume "
                         "with --resume). Implies --alerts")
    ap.add_argument("--prom", default=None, metavar="OUT.prom",
                    help="also export latest-round gauges as a "
                         "Prometheus-style textfile (requires --stream)")
    ap.add_argument("--alert-max-drift", type=float, default=100.0,
                    help="ConsensusDriftRule absolute ceiling (default "
                         "100.0; set tiny, e.g. 1e-9, to force an alert "
                         "for chaos/CI testing)")
    ap.add_argument("--profile-dir", default=None,
                    help="capture a jax.profiler trace into this directory "
                         "(TensorBoard-loadable)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="persist the trajectory carry + metrics for "
                         "crash-safe resume (single-trajectory runs; see "
                         "README 'Chaos & resume')")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="rounds per checkpoint segment (0 = one final "
                         "checkpoint)")
    ap.add_argument("--resume", action="store_true",
                    help="restore the latest checkpoint from "
                         "--checkpoint-dir and continue — the resumed "
                         "history is bitwise identical to an uninterrupted "
                         "run")
    ap.add_argument("--resume-step", type=int, default=None,
                    help="resume from this specific checkpoint step "
                         "instead of the latest")
    ap.add_argument("--stop-after", type=int, default=None,
                    help="deliberately exit at the first checkpoint "
                         "boundary >= this round (crash simulation for "
                         "CI/chaos testing)")
    args = ap.parse_args()

    from repro.core import TopologyConfig, make_topology
    from repro.data import (SyntheticImageConfig, make_synthetic_images,
                            partition_iid)
    from repro.models import make_mnist_mlp, nll_loss
    from repro.obs import (PhaseTimers, build_manifest, profiler_trace,
                           write_history)
    from repro.sim import SCENARIOS, get_scenario, run_monte_carlo, run_rounds
    from repro.strategies import available_strategies, get_strategy
    from repro.training import FLConfig

    if args.list:
        for name, sc in sorted(SCENARIOS.items()):
            dyn = "dynamic" if not sc.is_static else "static"
            grid = f" snr_grid={list(sc.snr_grid)}" if sc.snr_grid else ""
            pin = f" strategy={sc.strategy}" if sc.strategy else ""
            print(f"{name:16s} [{dyn}]{grid}{pin}")
        print(f"strategies: {', '.join(available_strategies())}")
        return

    scenario = get_scenario(args.scenario)
    # Resolve through the ONE registry: an explicit --strategy wins, else
    # the scenario's pinned default, else cwfl.  Unknown names fail here
    # with the registry's own message listing every registered strategy.
    strategy = (get_strategy(args.strategy) if args.strategy is not None
                else scenario.default_strategy())
    tcfg = TopologyConfig(num_clients=args.clients, num_hotspots=3)
    topo = make_topology(jax.random.PRNGKey(7), tcfg)
    dcfg = SyntheticImageConfig.mnist_like(args.train, args.test)
    (xtr, ytr), (xte, yte) = make_synthetic_images(jax.random.PRNGKey(1), dcfg)
    xs, ys = partition_iid(jax.random.PRNGKey(2), xtr, ytr, args.clients)
    init, apply = make_mnist_mlp(hidden=(args.hidden,))
    loss = lambda p, x, y: nll_loss(apply(p, x), y)
    cfg = FLConfig(strategy=strategy.name, rounds=args.rounds,
                   num_clusters=args.clusters, snr_db=args.snr_db,
                   eval_samples=args.test)

    is_sweep = args.seeds > 1 or bool(scenario.snr_grid)
    if args.shard == "mc" and not is_sweep:
        ap.error("--shard mc distributes a Monte-Carlo sweep; pass "
                 "--seeds N > 1 or a grid scenario (e.g. snr-sweep), or "
                 "use --shard clients for a single trajectory")
    if args.assert_match_vmap and args.shard != "mc":
        ap.error("--assert-match-vmap compares a --shard mc sweep "
                 "against the vmap path; nothing to compare here")
    mesh = None
    if args.shard is not None:
        from repro.launch.mesh import make_client_mesh, make_mc_mesh
        make = make_mc_mesh if args.shard == "mc" else make_client_mesh
        mesh = make(args.devices or None)
        print(f"shard={args.shard} mesh={dict(mesh.shape)}")

    is_single = not (args.seeds > 1 or bool(scenario.snr_grid))
    if args.checkpoint_dir is not None and not is_single:
        ap.error("--checkpoint-dir checkpoints ONE trajectory; Monte-Carlo "
                 "sweeps re-run cheaply per seed — drop --seeds / the grid "
                 "scenario")
    if args.checkpoint_dir is None and (args.resume
                                        or args.stop_after is not None):
        ap.error("--resume/--stop-after need --checkpoint-dir")

    if (args.alerts or args.abort_on_alert or args.prom) and not args.stream:
        ap.error("--alerts/--abort-on-alert/--prom ride the live stream; "
                 "add --stream OUT.jsonl")
    if args.abort_on_alert and args.checkpoint_dir is None:
        ap.error("--abort-on-alert stops at a checkpoint boundary so the "
                 "run stays resumable; add --checkpoint-dir (single "
                 "trajectory only)")

    telemetry = args.telemetry is not None or args.stream is not None
    # Checkpointed runs are multi-segment: phase timers stop meaning
    # anything (run_rounds refuses the combination), so drop them.
    timers = (PhaseTimers()
              if args.telemetry is not None and args.checkpoint_dir is None
              else None)

    stream = None
    manifest = None
    if args.stream is not None:
        from repro.obs import (JsonlStreamSink, Monitor, PrometheusSink,
                               RoundStream, default_rules)
        monitor = None
        if args.alerts or args.abort_on_alert:
            monitor = Monitor(default_rules(max_drift=args.alert_max_drift),
                              abort_on_alert=args.abort_on_alert)
        # Manifest first: a tailer picking up the file mid-run knows the
        # config before the first round record lands.  --resume appends so
        # the resumed rounds continue the same file.
        jsonl = JsonlStreamSink(args.stream, append=args.resume)
        manifest = build_manifest(cfg=cfg, scenario=scenario,
                                  strategy=strategy, mesh=mesh,
                                  extra={"shard": args.shard,
                                         "seeds": args.seeds,
                                         "clients": args.clients})
        jsonl.write({"type": "manifest", **manifest})
        sinks = [jsonl]
        if args.prom:
            sinks.append(PrometheusSink(args.prom))
        stream = RoundStream(sinks, monitor=monitor)

    print(f"scenario={args.scenario} strategy={strategy.name} "
          f"K={args.clients} rounds={args.rounds} seeds={args.seeds}"
          + (f" telemetry={args.telemetry}" if args.telemetry else "")
          + (f" stream={args.stream}" if args.stream else ""))
    t0 = time.perf_counter()
    if args.seeds > 1 or scenario.snr_grid:
        if args.shard == "clients":
            ap.error("--shard clients runs ONE trajectory (the K-client "
                     "axis is the parallel axis); drop --seeds / pick a "
                     "grid-free scenario, or use --shard mc for sweeps")
        with profiler_trace(args.profile_dir):
            h = run_monte_carlo(init, apply, loss, topo, xs, ys, xte, yte,
                                cfg, scenario=scenario, topo_cfg=tcfg,
                                seeds=args.seeds, shard=args.shard,
                                mesh=mesh, telemetry=telemetry, timers=timers,
                                stream=stream)
        wall = time.perf_counter() - t0
        if args.assert_match_vmap and args.shard == "mc":
            h_ref = run_monte_carlo(init, apply, loss, topo, xs, ys, xte,
                                    yte, cfg, scenario=scenario,
                                    topo_cfg=tcfg, seeds=args.seeds)
            for key in ("train_loss", "test_acc"):
                a = np.asarray(h[key])
                b = np.asarray(h_ref[key])
                bit = bool(np.array_equal(a, b))
                # SNR-grid sweeps batch nested on the vmap path and
                # flattened on the sharded path: XLA's batching-dependent
                # fusion costs ~1 ulp/round, compounding through SGD
                # (DESIGN.md §Sharded-MC) — seeds-only sweeps are bitwise.
                np.testing.assert_allclose(a, b, rtol=2e-5, atol=1e-5)
                print(f"  sharded == vmap [{key}]: "
                      f"{'bitwise' if bit else 'allclose(2e-5)'} OK")
        if timers is not None:
            with timers.phase("gather"):
                h["train_loss"] = np.asarray(h["train_loss"])
                h["test_acc"] = np.asarray(h["test_acc"])
        acc = np.asarray(h["test_acc"])            # (S, T) or (S, G, T)
        n_traj = int(np.prod(acc.shape[:-1]))
        if h["snr_grid"] is not None:
            for gi, snr in enumerate(np.asarray(h["snr_grid"])):
                fin = acc[:, gi, -1]
                print(f"  SNR {snr:5.1f} dB: final acc "
                      f"{fin.mean():.3f} ± {fin.std():.3f}  (over "
                      f"{acc.shape[0]} seeds)")
        else:
            fin = acc[:, -1]
            print(f"  final acc {fin.mean():.3f} ± {fin.std():.3f} "
                  f"(over {acc.shape[0]} seeds)")
        payload = {
            "scenario": args.scenario,
            "strategy": strategy.name,
            "shard": args.shard,
            "seeds": int(acc.shape[0]),
            "snr_grid": (None if h["snr_grid"] is None
                         else np.asarray(h["snr_grid"]).tolist()),
            "test_acc": acc.tolist(),
            "train_loss": np.asarray(h["train_loss"]).tolist(),
            "wall_seconds": wall,
            "trajectories": n_traj,
        }
    else:
        with profiler_trace(args.profile_dir):
            h = run_rounds(init, apply, loss, topo, xs, ys, xte, yte, cfg,
                           scenario=scenario, topo_cfg=tcfg,
                           shard=args.shard, mesh=mesh,
                           telemetry=telemetry, timers=timers,
                           checkpoint_dir=args.checkpoint_dir,
                           checkpoint_every=args.checkpoint_every,
                           resume=args.resume, resume_step=args.resume_step,
                           stop_after=args.stop_after, stream=stream)
        wall = time.perf_counter() - t0
        if timers is not None:
            with timers.phase("gather"):
                h["train_loss"] = np.asarray(h["train_loss"])
                h["test_acc"] = np.asarray(h["test_acc"])
        acc = np.asarray(h["test_acc"])
        n_traj = 1
        for r, (l, a) in enumerate(zip(np.asarray(h["train_loss"]), acc)):
            print(f"  round {r + 1:2d}  loss={l:.3f}  acc={a:.3f}")
        payload = {
            "scenario": args.scenario,
            "strategy": strategy.name,
            "shard": args.shard,
            "seeds": 1,
            "test_acc": acc.tolist(),
            "train_loss": np.asarray(h["train_loss"]).tolist(),
            "wall_seconds": wall,
            "trajectories": 1,
        }
    total_rounds = n_traj * int(acc.shape[-1])   # may be < --rounds when
    # --stop-after killed a checkpointed run at a segment boundary
    print(f"  {total_rounds} rounds total in {wall:.1f}s "
          f"({total_rounds / wall:.2f} rounds/s incl. compile)")
    if stream is not None:
        abort = stream.should_abort
        print(f"  stream: {stream.emitted} records -> {args.stream}"
              + (f" ({stream.dropped} off-rank/off-scope dropped)"
                 if stream.dropped else "")
              + (f" [{len(stream.errors)} tap errors]"
                 if stream.errors else ""))
        if stream.monitor is not None:
            s = stream.monitor.summary()
            if s["alerts"]:
                by = ", ".join(f"{k}×{v}" for k, v in s["by_rule"].items())
                print(f"  ALERTS: {s['alerts']} ({by})"
                      + ("; run aborted at checkpoint boundary — resume "
                         "with --resume" if abort else ""))
            else:
                print("  alerts: none")
        stream.close()
    if manifest is None and (telemetry or args.out):
        manifest = build_manifest(cfg=cfg, scenario=scenario,
                                  strategy=strategy, mesh=mesh,
                                  extra={"shard": args.shard,
                                         "seeds": args.seeds,
                                         "clients": args.clients})
    if args.telemetry is not None:
        if timers is not None:
            for name, secs in timers.as_dict().items():
                print(f"  phase {name:14s} {secs:8.3f}s")
        n_rec = write_history(args.telemetry, h, manifest=manifest,
                              timings=timers.as_dict() if timers else None)
        print(f"  wrote {args.telemetry} ({n_rec} records); render with "
              f"examples/obs_report.py")
    if args.out:
        payload["run_manifest"] = manifest
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"  wrote {args.out}")


if __name__ == "__main__":
    main()
