"""Batched serving demo: prefill + greedy decode with the delta-cache engine
(read-only caches inside the step; the engine owns cache writes).

    PYTHONPATH=src python examples/serve_decode.py --arch gemma2-9b
(reduced config variants of the assigned architectures; CPU-friendly)
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config
from repro.models.inputs import make_batch
from repro.models.transformer import init_params
from repro.training.serve import greedy_decode


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=ARCH_NAMES)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    print(f"serving {args.arch} (reduced): {cfg.num_layers}L "
          f"d={cfg.d_model} pattern={[s.mixer for s in cfg.pattern]}")
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(jax.random.PRNGKey(1), cfg, args.prompt_len,
                       args.batch, kind="prefill")

    t0 = time.time()
    toks, last_logits = greedy_decode(params, batch, cfg, args.tokens)
    dt = time.time() - t0
    print(f"decoded {args.batch}×{args.tokens} tokens in {dt:.1f}s "
          f"({args.batch*args.tokens/dt:.1f} tok/s on CPU)")
    for b in range(min(args.batch, 2)):
        print(f"  seq{b}: {toks[b].tolist()}")
    assert bool(jnp.all(jnp.isfinite(last_logits.astype(jnp.float32))))
    print("finite logits ✓  (greedy continuation of random-weight model)")


if __name__ == "__main__":
    main()
